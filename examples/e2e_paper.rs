//! End-to-end driver: exercises the full system on real (scaled) workloads
//! and reproduces the paper's headline result — GraphMP-C beating the
//! out-of-core baselines by order-of-magnitude factors — plus a three-layer
//! validation pass where the AOT Pallas kernels run the same computation
//! through PJRT.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example e2e_paper            # twitter-s (default)
//! cargo run --release --example e2e_paper -- --dataset uk2007-s --throttle-mbps 300
//! ```

use std::sync::Arc;

use graphmp::apps::{self, VertexProgram};
use graphmp::baselines;
use graphmp::cache::Codec;
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{ensure_dataset, run_graphmp, GraphMpVariant};
use graphmp::coordinator::report;
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::runtime::ShardRuntime;
use graphmp::storage::io;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["quick"])?;
    let dataset = Dataset::by_name(args.get_or("dataset", "twitter-s"))?;
    let iters = args.get_usize("iters", 10)?;
    // default to the paper-era disk model (see DESIGN.md §3); 0 disables
    let throttle_mbps = args.get_usize("throttle-mbps", 300)? as u64;

    println!(
        "== e2e: {} (stands in for {}) |V|={} |E|={} ==",
        dataset.name,
        dataset.stands_in_for,
        humansize::count(dataset.num_vertices() as u64),
        humansize::count(dataset.num_edges),
    );
    let dir = ensure_dataset(dataset)?;
    let edges = dataset.generate();
    let n = dataset.num_vertices();

    if throttle_mbps > 0 {
        io::set_throttle(throttle_mbps << 20);
        println!("HDD throttle: {throttle_mbps} MiB/s (paper-era disk model)");
    }

    let mut table = Table::new(
        &format!("e2e {} — {iters}-iteration totals (PR/SSSP/WCC)", dataset.name),
        &["system", "app", "time", "read", "written", "vs GraphMP-C"],
    );

    let app_list: Vec<Box<dyn VertexProgram>> = vec![
        apps::by_name("pagerank")?.into_f32()?,
        apps::by_name("sssp")?.into_f32()?,
        apps::by_name("wcc")?.into_f32()?,
    ];

    for app in &app_list {
        // GraphMP-C is the reference everything is normalized against
        let (gc, _) =
            run_graphmp(&dir, GraphMpVariant::Cached(Codec::SnapLite), true, app.as_ref(), iters)?;
        let gc_time = gc.stats.total_wall;
        table.row(&[
            "GraphMP-C".into(),
            app.name().into(),
            humansize::duration(gc_time),
            humansize::bytes(gc.stats.total_bytes_read()),
            humansize::bytes(gc.stats.total_bytes_written()),
            "1.0".into(),
        ]);

        let (gnc, _) = run_graphmp(&dir, GraphMpVariant::NoCache, true, app.as_ref(), iters)?;
        table.row(&[
            "GraphMP-NC".into(),
            app.name().into(),
            humansize::duration(gnc.stats.total_wall),
            humansize::bytes(gnc.stats.total_bytes_read()),
            humansize::bytes(gnc.stats.total_bytes_written()),
            report::ratio(gc_time.as_secs_f64(), gnc.stats.total_wall.as_secs_f64()),
        ]);

        for sys in ["psw", "esg", "dsw", "vsp"] {
            let work = std::env::temp_dir().join(format!("graphmp_e2e_{sys}"));
            let mut eng = baselines::by_name(sys, work)?;
            eng.prepare(&edges, n)?;
            let run = eng.run(app.as_ref(), iters)?;
            table.row(&[
                eng.name().into(),
                app.name().into(),
                humansize::duration(run.total_wall),
                humansize::bytes(run.io.bytes_read),
                humansize::bytes(run.io.bytes_written),
                report::ratio(gc_time.as_secs_f64(), run.total_wall.as_secs_f64()),
            ]);
        }
    }
    io::set_throttle(0);
    table.print();

    // --- three-layer validation: the AOT kernels on the hot path ---------
    println!("\n== three-layer validation (PJRT/Pallas backend) ==");
    match ShardRuntime::load(std::path::Path::new("artifacts")) {
        Err(e) => println!("SKIPPED: artifacts not built ({e})"),
        Ok(rt) => {
            let rt = Arc::new(rt);
            let engine = VswEngine::open(
                dir.clone(),
                EngineConfig {
                    max_iters: 2,
                    backend: Backend::Xla(rt.clone()),
                    ..Default::default()
                },
            )?;
            let xla = engine.run(&apps::PageRank::default())?;
            let native_engine = VswEngine::open(
                dir.clone(),
                EngineConfig { max_iters: 2, ..Default::default() },
            )?;
            let native = native_engine.run(&apps::PageRank::default())?;
            let max_dev = xla
                .values
                .iter()
                .zip(&native.values)
                .map(|(a, b)| (a - b).abs() / b.abs().max(1e-9))
                .fold(0.0f32, f32::max);
            println!(
                "PageRank ×2 iters via {} PJRT kernel calls: max relative deviation {:.2e} (native vs xla)",
                rt.call_count(),
                max_dev
            );
            assert!(max_dev < 1e-4, "three-layer path diverged from native");
            println!("three-layer composition VERIFIED");
        }
    }

    // persist for EXPERIMENTS.md
    report::append_markdown(&report::results_path(), &table)?;
    println!("\nresults appended to {}", report::results_path().display());
    Ok(())
}
