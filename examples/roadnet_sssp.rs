//! Road-network SSSP — the opposite regime from webgraphs: low degree, huge
//! diameter, tiny frontier.  This is where selective scheduling (§II-D.1)
//! pays off hardest: after a few iterations only the shards containing the
//! frontier are touched, and everything else is skipped via Bloom probes.
//!
//! ```sh
//! cargo run --release --example roadnet_sssp
//! ```

use graphmp::apps::Sssp;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    // 200×200 lattice + 60 random highways: 40K intersections, ~160K roads
    let (rows, cols) = (200usize, 200usize);
    let edges = generator::grid2d(rows, cols, 60, 7);
    let n = rows * cols;
    println!("road network: {} intersections, {} directed road segments", n, edges.len());

    let dir = DatasetDir::new(std::env::temp_dir().join("graphmp_roadnet.gmp"));
    let _ = std::fs::remove_dir_all(&dir.root);
    preprocess("roadnet", &edges, n, &dir, &PreprocessConfig::default())?;

    let source = 0u32; // top-left corner
    for (label, selective) in [("selective ON ", true), ("selective OFF", false)] {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig {
                selective,
                // the frontier is a wavefront: a tiny fraction of |V|, so
                // engage Bloom probing as soon as it drops under 10%
                selective_threshold: 0.10,
                ..Default::default()
            },
        )?;
        let result = engine.run(&Sssp { source })?;
        let s = &result.stats;
        let skipped: usize = s.iters.iter().map(|i| i.shards_skipped).sum();
        let processed: usize = s.iters.iter().map(|i| i.shards_processed).sum();
        println!(
            "{label}: {:3} iterations, {:>9}, shards processed {processed:6} skipped {skipped:6}",
            s.num_iters(),
            humansize::duration(s.total_wall),
        );
        if selective {
            // distance map sanity: corner-to-corner distance is rows+cols-2
            // unless a highway shortcuts it
            let far = (n - 1) as usize;
            let d = result.values[far];
            println!(
                "  distance to opposite corner: {} (lattice-only would be {})",
                d,
                rows + cols - 2
            );
            let reachable = result.values.iter().filter(|v| v.is_finite()).count();
            println!("  reachable intersections: {reachable}/{n}");
        }
    }
    Ok(())
}
