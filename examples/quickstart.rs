//! Quickstart: the smallest end-to-end GraphMP pipeline.
//!
//! 1. generate a small power-law graph;
//! 2. preprocess it into destination-sharded CSR + Bloom filters;
//! 3. run PageRank — on the **three-layer AOT path** (rust → PJRT →
//!    JAX/Pallas artifact) when `artifacts/` is built, else natively;
//! 4. print per-iteration stats and the top-ranked vertices.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use graphmp::apps::PageRank;
use graphmp::coordinator::datasets::Dataset;
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::runtime::ShardRuntime;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    // 1. a "small" dataset: 4K vertices, 120K edges, power-law
    let dataset = Dataset::by_name("small")?;
    let edges = dataset.generate();
    println!(
        "generated {}: |V|={} |E|={}",
        dataset.name,
        dataset.num_vertices(),
        edges.len()
    );

    // 2. preprocess (the paper's 4-step pipeline, §II-B)
    let dir = DatasetDir::new(std::env::temp_dir().join("graphmp_quickstart.gmp"));
    let _ = std::fs::remove_dir_all(&dir.root);
    let out = preprocess(
        dataset.name,
        &edges,
        dataset.num_vertices(),
        &dir,
        &PreprocessConfig::default(),
    )?;
    println!(
        "preprocessed into {} shards (bloom filters: {})",
        out.property.num_shards(),
        humansize::bytes(out.bloom_bytes)
    );

    // 3. pick the backend: AOT artifacts if available
    let artifact_dir = std::path::Path::new("artifacts");
    let backend = match ShardRuntime::load(artifact_dir) {
        Ok(rt) => {
            println!("using the xla backend (AOT Pallas kernels via PJRT)");
            Backend::Xla(Arc::new(rt))
        }
        Err(e) => {
            println!("artifacts not available ({e}); using the native backend");
            Backend::Native
        }
    };

    let cfg = EngineConfig { max_iters: 10, backend, ..Default::default() };
    let engine = VswEngine::open(dir, cfg)?;
    let result = engine.run(&PageRank::default())?;

    // 4. report
    for it in &result.stats.iters {
        println!(
            "iter {:2}: {:>9}  active {:.2}%  cache-hits {}",
            it.iter,
            humansize::duration(it.wall),
            it.active_ratio * 100.0,
            it.cache_hits
        );
    }
    let mut ranked: Vec<(usize, f32)> = result.values.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop-5 vertices by rank:");
    for (v, r) in ranked.iter().take(5) {
        println!("  v{v:<6} rank {r:.6}");
    }
    println!(
        "\nprocessed {} in {} ({})",
        humansize::count(result.stats.edges_processed),
        humansize::duration(result.stats.total_wall),
        humansize::rate(result.stats.edges_processed, result.stats.total_wall)
    );
    Ok(())
}
