//! Webgraph analytics — the paper's motivating scenario (§I): connectivity +
//! ranking over a power-law web crawl on one machine.
//!
//! Runs the full pipeline on `twitter-s` (the scaled Twitter stand-in):
//! WCC to find the crawl's weak components, then PageRank restricted
//! reporting to the giant component, comparing GraphMP-C vs GraphMP-NC
//! cache behaviour along the way.
//!
//! ```sh
//! cargo run --release --example webgraph_analytics
//! ```

use graphmp::apps::{PageRank, Wcc};
use graphmp::cache::Codec;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{ensure_dataset, run_graphmp, GraphMpVariant};
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let dataset = Dataset::by_name("twitter-s")?;
    println!(
        "== webgraph analytics on {} (stands in for {}) ==",
        dataset.name, dataset.stands_in_for
    );
    let dir = ensure_dataset(dataset)?;

    // --- pass 1: weakly connected components -----------------------------
    let (wcc, load) = run_graphmp(&dir, GraphMpVariant::Cached(Codec::SnapLite), true, &Wcc, 0)?;
    println!(
        "WCC: {} iterations in {} (load {})",
        wcc.stats.num_iters(),
        humansize::duration(wcc.stats.total_wall),
        humansize::duration(load)
    );
    let mut counts = std::collections::HashMap::new();
    for &c in &wcc.values {
        *counts.entry(c as u32).or_insert(0u64) += 1;
    }
    let mut comps: Vec<(u32, u64)> = counts.into_iter().collect();
    comps.sort_by_key(|&(_, n)| std::cmp::Reverse(n));
    println!("components: {} total; largest 3:", comps.len());
    for (id, n) in comps.iter().take(3) {
        println!(
            "  component {:>8}: {:>8} vertices ({:.1}%)",
            id,
            n,
            100.0 * *n as f64 / wcc.values.len() as f64
        );
    }

    // --- pass 2: PageRank, cache-mode comparison ---------------------------
    println!("\nPageRank (10 iters), GraphMP-C vs GraphMP-NC:");
    for variant in [
        GraphMpVariant::Cached(Codec::SnapLite),
        GraphMpVariant::NoCache,
    ] {
        let (pr, _) = run_graphmp(&dir, variant, true, &PageRank::default(), 10)?;
        let read: u64 = pr.stats.iters.iter().map(|i| i.io.bytes_read).sum();
        println!(
            "  {:<22} total {:>9}  disk-read {:>10}  rate {}",
            variant.label(),
            humansize::duration(pr.stats.total_wall),
            humansize::bytes(read),
            humansize::rate(pr.stats.edges_processed, pr.stats.total_wall)
        );
        let giant = comps[0].0;
        let mut best = (0usize, f32::MIN);
        for (v, &r) in pr.values.iter().enumerate() {
            if wcc.values[v] as u32 == giant && r > best.1 {
                best = (v, r);
            }
        }
        println!("      top page in giant component: v{} (rank {:.6})", best.0, best.1);
    }
    Ok(())
}
