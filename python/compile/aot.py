"""AOT compile path: lower the L2 shard programs to HLO **text** artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the rust side reassigns ids, so text round-trips
cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per shard program plus ``manifest.json``
recording the kernel geometry; the rust runtime refuses to run against a
manifest whose geometry disagrees with its compiled-in constants.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.segsum import E_MAX, TILE_E, V_MAX

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs():
    f32e = jax.ShapeDtypeStruct((E_MAX,), jnp.float32)
    i32e = jax.ShapeDtypeStruct((E_MAX,), jnp.int32)
    f32v = jax.ShapeDtypeStruct((V_MAX,), jnp.float32)
    f32s = jax.ShapeDtypeStruct((1,), jnp.float32)
    return {
        # name -> (fn, example args, input signature for the manifest)
        "pr_shard": (model.pr_shard, (f32e, i32e, f32s),
                     ["contrib:f32[E]", "dst:i32[E]", "inv_n:f32[1]"]),
        "relaxmin_shard": (model.relaxmin_shard, (f32e, i32e, f32v),
                           ["contrib:f32[E]", "dst:i32[E]", "old:f32[V]"]),
        "segsum_shard": (model.segsum_shard, (f32e, i32e),
                         ["contrib:f32[E]", "dst:i32[E]"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    wanted = set(args.only.split(",")) if args.only else None
    manifest = {
        "version": MANIFEST_VERSION,
        "geometry": {"v_max": V_MAX, "e_max": E_MAX, "tile_e": TILE_E},
        "artifacts": {},
    }
    for name, (fn, example, sig) in specs().items():
        if wanted is not None and name not in wanted:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": sig,
            "output": "f32[V]",
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
