"""Layer-2: GraphMP's per-shard vertex-update programs as JAX functions.

Each function is the compute half of one VSW sliding-window step
(Algorithm 1, line 7-8 of the paper): the rust coordinator has already
gathered per-edge contributions from ``SrcVertexArray`` (the L3 side owns the
CSR walk and the ``rank/out_deg`` transform); these functions perform the
per-destination reduction + apply on top of the Pallas kernels and hand back
the slice of ``DstVertexArray`` covered by the shard's vertex interval.

All functions are lowered AOT by ``aot.py`` into ``artifacts/*.hlo.txt`` and
executed from rust via PJRT — python never runs on the iteration path.
"""

import jax.numpy as jnp

from .kernels.segmin import segmin
from .kernels.segsum import segsum

DAMPING = 0.85


def pr_shard(contrib, dst, inv_n):
    """PageRank (Algorithm 2, PR_Update): new[v] = 0.15/N + 0.85 * sum.

    contrib[e] = rank[src(e)] / out_deg(src(e)), padding 0.
    inv_n: f32[1] = 1 / |V| of the global graph.
    Returns f32[V_MAX]: updated values for the shard's vertex interval.
    """
    s = segsum(contrib, dst)
    return (1.0 - DAMPING) * inv_n[0] + DAMPING * s


def relaxmin_shard(contrib, dst, old):
    """SSSP/WCC (Algorithm 2, SSSP_Update / WCC_Update).

    SSSP: contrib[e] = dist[src(e)] + val(e)   (unweighted: +1), padding +inf.
    WCC:  contrib[e] = comp[src(e)], padding +inf.
    new[v] = min(old[v], segmin(contrib)[v]).
    """
    m = segmin(contrib, dst)
    return jnp.minimum(old, m)


def segsum_shard(contrib, dst):
    """Raw segmented sum — the generic SpMV building block (y = A^T x per
    shard), exposed as its own artifact for the spmv app and micro-benches."""
    return segsum(contrib, dst)
