"""Pure-jnp reference oracles for the Pallas shard kernels.

These are the ground-truth semantics the Pallas kernels in ``segsum.py`` /
``segmin.py`` must match bit-for-bit (up to f32 accumulation order).  They are
used by pytest (``python/tests``) and never shipped in an artifact.

Shard-kernel contract (see DESIGN.md, "Kernel geometry"):

* ``contrib``  -- f32[E_MAX]  per-edge contribution, already gathered by the
  rust coordinator (e.g. ``rank[src]/out_deg[src]`` for PageRank).  Padding
  lanes carry the reduction identity (0 for sum, +inf for min).
* ``dst``      -- i32[E_MAX]  *local* destination index in ``[0, V_MAX)``.
  Padding lanes may point anywhere; their contribution is the identity.
* result       -- f32[V_MAX]  per-destination reduction.
"""

import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def segsum_ref(contrib, dst, v_max: int):
    """Segmented sum: out[v] = sum over edges e with dst[e]==v of contrib[e]."""
    out = jnp.zeros((v_max,), dtype=contrib.dtype)
    return out.at[dst].add(contrib)


def segmin_ref(contrib, dst, v_max: int):
    """Segmented min: out[v] = min over edges e with dst[e]==v of contrib[e].

    Vertices with no incoming edge get +inf.
    """
    out = jnp.full((v_max,), INF, dtype=contrib.dtype)
    return out.at[dst].min(contrib)


def pr_shard_ref(contrib, dst, inv_n, v_max: int, damping: float = 0.85):
    """PageRank shard update: new[v] = (1-d)/N + d * segsum(contrib)[v].

    ``inv_n`` is a f32[1] array holding 1/|V| of the *global* graph (the shard
    only sees V_MAX local slots).
    """
    s = segsum_ref(contrib, dst, v_max)
    return (1.0 - damping) * inv_n[0] + damping * s


def relaxmin_shard_ref(contrib, dst, old, v_max: int):
    """SSSP/WCC shard update: new[v] = min(old[v], segmin(contrib)[v]).

    SSSP feeds contrib = dist[src] + w(src,v); WCC feeds contrib = comp[src].
    """
    m = segmin_ref(contrib, dst, v_max)
    return jnp.minimum(old, m)
