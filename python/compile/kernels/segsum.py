"""Pallas segmented-sum kernel: the PageRank-style scatter-add hot-spot.

Hardware adaptation (DESIGN.md "Hardware adaptation"): GraphMP's C++/OpenMP
inner loop is a gather over CSR adjacency followed by a per-destination
accumulate.  A TPU has no efficient random scatter, so we recast the
scatter-add as a *one-hot matmul* that runs on the MXU systolic array:

    out[V] += contrib[1, T] @ onehot(dst_tile)[T, V]

The edge stream is tiled into blocks of TILE_E edges; each grid step builds
the one-hot expansion of its destination indices in VMEM and feeds the MXU.
BlockSpec expresses the HBM->VMEM schedule the paper's sliding window does
with disk->memory shard loads: the edge arrays stream tile by tile while the
V_MAX output accumulator stays resident in VMEM across the whole grid.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter to
plain HLO (see /opt/xla-example/README.md).  Numeric behaviour is identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Canonical shard-kernel geometry.  Must match `rust/src/runtime/geometry.rs`
# and is recorded in artifacts/manifest.json by aot.py.
V_MAX = 2048       # padded vertices per shard interval
E_MAX = 16384      # padded edges per shard
TILE_E = 1024      # edges per grid step (one MXU pass each)


def _segsum_kernel(contrib_ref, dst_ref, out_ref):
    """One grid step: scatter-add TILE_E edges into the V_MAX accumulator."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    contrib = contrib_ref[...]                      # f32[TILE_E]
    dst = dst_ref[...]                              # i32[TILE_E]
    # One-hot expansion of the destination indices: f32[TILE_E, V_MAX].
    cols = jax.lax.broadcasted_iota(jnp.int32, (contrib.shape[0], out_ref.shape[0]), 1)
    onehot = (dst[:, None] == cols).astype(contrib.dtype)
    # MXU pass: [1, TILE_E] @ [TILE_E, V_MAX] -> [1, V_MAX].
    tile_sum = jnp.dot(contrib[None, :], onehot,
                       preferred_element_type=jnp.float32)[0]
    out_ref[...] += tile_sum


@functools.partial(jax.jit, static_argnames=("v_max", "tile_e"))
def segsum(contrib, dst, *, v_max: int = V_MAX, tile_e: int = TILE_E):
    """out[v] = sum of contrib[e] over edges e with dst[e] == v.

    contrib: f32[E] with E % tile_e == 0 (padding lanes carry 0.0).
    dst:     i32[E] local destination indices in [0, v_max).
    """
    e = contrib.shape[0]
    assert e % tile_e == 0, f"edge count {e} not a multiple of tile {tile_e}"
    grid = e // tile_e
    return pl.pallas_call(
        _segsum_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((tile_e,), lambda i: (i,)),
        ],
        # The accumulator is one block for the whole grid: stays in VMEM.
        out_specs=pl.BlockSpec((v_max,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((v_max,), jnp.float32),
        interpret=True,
    )(contrib, dst)
