"""Pallas segmented-min kernel: the SSSP/WCC relax hot-spot.

Min has no matmul form, so unlike ``segsum`` the MXU cannot help; instead we
do a masked broadcast-reduce on the VPU:

    masked[T, V] = where(dst_tile one-hot, contrib, +inf)
    out[V]       = min(out, min over T of masked)

Same streaming structure as segsum: edge arrays are tiled TILE_E at a time
through VMEM while the V_MAX accumulator stays resident.  Padding lanes must
carry +inf so they are identity under min.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .segsum import E_MAX, TILE_E, V_MAX  # shared geometry  # noqa: F401

# NB: plain python float, not a jnp scalar — pallas_call rejects kernels
# that capture traced constants.
_INF = float("inf")


def _segmin_kernel(contrib_ref, dst_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INF)

    contrib = contrib_ref[...]                      # f32[TILE_E]
    dst = dst_ref[...]                              # i32[TILE_E]
    cols = jax.lax.broadcasted_iota(jnp.int32, (contrib.shape[0], out_ref.shape[0]), 1)
    masked = jnp.where(dst[:, None] == cols, contrib[:, None], _INF)
    tile_min = jnp.min(masked, axis=0)              # f32[V_MAX]
    out_ref[...] = jnp.minimum(out_ref[...], tile_min)


@functools.partial(jax.jit, static_argnames=("v_max", "tile_e"))
def segmin(contrib, dst, *, v_max: int = V_MAX, tile_e: int = TILE_E):
    """out[v] = min of contrib[e] over edges e with dst[e] == v (else +inf).

    contrib: f32[E] with E % tile_e == 0 (padding lanes carry +inf).
    dst:     i32[E] local destination indices in [0, v_max).
    """
    e = contrib.shape[0]
    assert e % tile_e == 0, f"edge count {e} not a multiple of tile {tile_e}"
    grid = e // tile_e
    return pl.pallas_call(
        _segmin_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((tile_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((v_max,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((v_max,), jnp.float32),
        interpret=True,
    )(contrib, dst)
