"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes-compatible value ranges and destination
distributions; every case asserts allclose against ``ref.py``.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import pr_shard_ref, relaxmin_shard_ref, segmin_ref, segsum_ref
from compile.kernels.segmin import segmin
from compile.kernels.segsum import E_MAX, TILE_E, V_MAX, segsum

RNG = np.random.default_rng(0xC0FFEE)


def mk_inputs(n_edges, v_max, *, pad_to=None, identity=0.0, skew=False):
    """Random contrib/dst arrays, optionally padded to a tile multiple."""
    contrib = RNG.standard_normal(n_edges).astype(np.float32)
    if skew:
        # power-law-ish destination concentration (shard hot rows)
        raw = RNG.zipf(1.5, size=n_edges)
        dst = ((raw - 1) % v_max).astype(np.int32)
    else:
        dst = RNG.integers(0, v_max, n_edges).astype(np.int32)
    if pad_to is not None:
        pad = (-len(contrib)) % pad_to
        contrib = np.concatenate([contrib, np.full(pad, identity, np.float32)])
        dst = np.concatenate([dst, np.zeros(pad, np.int32)])
    return jnp.asarray(contrib), jnp.asarray(dst)


# ---------------------------------------------------------------- segsum

class TestSegsum:
    def test_full_geometry(self):
        contrib, dst = mk_inputs(E_MAX, V_MAX)
        got = segsum(contrib, dst)
        want = segsum_ref(contrib, dst, V_MAX)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_all_edges_one_destination(self):
        contrib = jnp.ones((E_MAX,), jnp.float32)
        dst = jnp.zeros((E_MAX,), jnp.int32)
        got = segsum(contrib, dst)
        assert got[0] == E_MAX
        assert float(jnp.abs(got[1:]).max()) == 0.0

    def test_empty_contributions_padding(self):
        # all-identity input => zero output
        contrib = jnp.zeros((E_MAX,), jnp.float32)
        dst = jnp.zeros((E_MAX,), jnp.int32)
        assert float(jnp.abs(segsum(contrib, dst)).max()) == 0.0

    def test_skewed_destinations(self):
        contrib, dst = mk_inputs(E_MAX, V_MAX, skew=True)
        got = segsum(contrib, dst)
        want = segsum_ref(contrib, dst, V_MAX)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        n_tiles=st.integers(1, 4),
        v_max=st.sampled_from([8, 128, 2048]),
        tile=st.sampled_from([128, 1024]),
    )
    def test_hypothesis_shapes(self, n_tiles, v_max, tile):
        contrib, dst = mk_inputs(n_tiles * tile, v_max)
        got = segsum(contrib, dst, v_max=v_max, tile_e=tile)
        want = segsum_ref(contrib, dst, v_max)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_untiled_edge_count(self):
        with pytest.raises(AssertionError):
            segsum(jnp.zeros((100,), jnp.float32), jnp.zeros((100,), jnp.int32))


# ---------------------------------------------------------------- segmin

class TestSegmin:
    def test_full_geometry(self):
        contrib, dst = mk_inputs(E_MAX, V_MAX)
        got = segmin(contrib, dst)
        want = segmin_ref(contrib, dst, V_MAX)
        np.testing.assert_array_equal(got, want)  # min is exact in f32

    def test_untouched_lanes_are_inf(self):
        contrib = jnp.zeros((TILE_E,), jnp.float32)
        dst = jnp.zeros((TILE_E,), jnp.int32)
        got = segmin(contrib, dst, v_max=16, tile_e=TILE_E)
        assert got[0] == 0.0
        assert np.all(np.isinf(np.asarray(got[1:])))

    def test_inf_padding_is_identity(self):
        base = jnp.asarray(np.float32([3.0, 1.0, 2.0] + [np.inf] * (TILE_E - 3)))
        dst = jnp.zeros((TILE_E,), jnp.int32)
        got = segmin(base, dst, v_max=4, tile_e=TILE_E)
        assert got[0] == 1.0

    @settings(max_examples=20, deadline=None)
    @given(
        n_tiles=st.integers(1, 4),
        v_max=st.sampled_from([8, 128, 2048]),
        tile=st.sampled_from([128, 1024]),
    )
    def test_hypothesis_shapes(self, n_tiles, v_max, tile):
        contrib, dst = mk_inputs(n_tiles * tile, v_max)
        got = segmin(contrib, dst, v_max=v_max, tile_e=tile)
        want = segmin_ref(contrib, dst, v_max)
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------ L2 programs

class TestModelPrograms:
    def test_pr_shard(self):
        from compile import model

        contrib, dst = mk_inputs(E_MAX, V_MAX)
        contrib = jnp.abs(contrib)  # ranks are positive
        inv_n = jnp.asarray([1.0 / 1000.0], jnp.float32)
        got = model.pr_shard(contrib, dst, inv_n)
        want = pr_shard_ref(contrib, dst, inv_n, V_MAX)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_relaxmin_shard(self):
        from compile import model

        contrib, dst = mk_inputs(E_MAX, V_MAX, identity=np.inf)
        old = jnp.asarray(RNG.standard_normal(V_MAX).astype(np.float32))
        got = model.relaxmin_shard(contrib, dst, old)
        want = relaxmin_shard_ref(contrib, dst, old, V_MAX)
        np.testing.assert_array_equal(got, want)

    def test_relaxmin_never_increases(self):
        from compile import model

        contrib, dst = mk_inputs(E_MAX, V_MAX)
        old = jnp.asarray(RNG.standard_normal(V_MAX).astype(np.float32))
        got = model.relaxmin_shard(contrib, dst, old)
        assert bool(jnp.all(got <= old))

    def test_segsum_shard_equals_kernel(self):
        from compile import model

        contrib, dst = mk_inputs(E_MAX, V_MAX)
        np.testing.assert_array_equal(
            model.segsum_shard(contrib, dst), segsum(contrib, dst)
        )
