"""AOT pipeline tests: artifacts lower to loadable HLO text with the right
manifest, and the lowered HLO has the expected structure (no python left,
fixed shapes, one fusion-friendly reduction pass)."""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import specs, to_hlo_text
from compile.kernels.segsum import E_MAX, TILE_E, V_MAX

import jax


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return out


def test_manifest_contents(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    assert m["version"] == 1
    assert m["geometry"] == {"v_max": V_MAX, "e_max": E_MAX, "tile_e": TILE_E}
    assert set(m["artifacts"]) == {"pr_shard", "relaxmin_shard", "segsum_shard"}
    for name, entry in m["artifacts"].items():
        path = artifacts / entry["file"]
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"


def test_hlo_is_fixed_shape_and_python_free(artifacts):
    for f in artifacts.glob("*.hlo.txt"):
        text = f.read_text()
        # no dynamic shapes, no host callbacks (python on the request path)
        assert "<=*" not in text, f"{f.name}: dynamic dim"
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), (
            f"{f.name}: Mosaic custom-call cannot run on CPU PJRT"
        )
        assert f"f32[{E_MAX}]" in text, f"{f.name}: missing edge-shaped input"


def test_lowering_is_deterministic():
    name, (fn, example, _) = next(iter(specs().items()))
    a = to_hlo_text(jax.jit(fn).lower(*example))
    b = to_hlo_text(jax.jit(fn).lower(*example))
    assert a == b, f"{name}: non-deterministic lowering"


def test_hlo_single_edge_pass(artifacts):
    """L2 perf contract: each artifact streams the edge arrays once — the
    number of E_MAX-shaped parameters equals the number of edge inputs, and
    the grid loop (while/dynamic-slice structure) appears once."""
    text = (artifacts / "segsum_shard.hlo.txt").read_text()
    loops = sum(1 for line in text.splitlines() if " while(" in line)
    assert loops == 1, f"expected exactly one grid loop, found {loops}"
