#!/usr/bin/env python3
"""Golden-fixture generator for rust/tests/golden_fixtures.rs.

Reproduces the Rust in-memory reference (Algorithm 2 swept synchronously,
`update_weighted` semantics) in IEEE-754 binary32/binary64 arithmetic via
numpy, over the fixture graph defined below — the same closed-form graph
the Rust test rebuilds.  Running this script must reproduce the committed
files under rust/tests/fixtures/ bit-for-bit; the Rust test fails loudly if
the engine, the Rust reference, or these fixtures ever disagree.

Fixture format: one value per line, 48 lines per app.
  * f32 lanes: 8 hex digits of the IEEE bit pattern (to_bits)
  * f64 lanes: 16 hex digits
  * u32/u64 lanes: decimal

Usage: python3 python/tools/gen_fixtures.py [--check]
  --check: verify the committed fixtures instead of rewriting them.
"""

import os
import struct
import sys

import numpy as np

N = 48
M = 160
MAX_ITERS = 1000

F32 = np.float32
F64 = np.float64
INF32 = np.float32(np.inf)
INF64 = np.float64(np.inf)


def fixture_graph():
    """(src, dst, weight) triples — must match golden_fixtures.rs.

    Two affine edge families: the second breaks the one-successor
    degeneracy of the first so degrees (and PageRank) are non-uniform.
    """
    edges = []
    weights = []
    for i in range(M):
        s = (7 * i) % N
        d = (13 * i + 5) % N
        w = np.float32((i % 7) + 1) * np.float32(0.25)
        edges.append((s, d))
        weights.append(np.float32(w))
    for i in range(M // 2):
        s = (5 * i + 11) % N
        d = (11 * i + 2) % N
        w = np.float32((i % 5) + 1) * np.float32(0.5)
        edges.append((s, d))
        weights.append(np.float32(w))
    return edges, weights


def adjacency(edges, weights):
    in_adj = [[] for _ in range(N)]
    in_w = [[] for _ in range(N)]
    out_deg = [0] * N
    for (s, d), w in zip(edges, weights):
        in_adj[d].append(s)
        in_w[d].append(w)
        out_deg[s] += 1
    return in_adj, in_w, out_deg


def hash64(x):
    mask = (1 << 64) - 1
    z = (x + 0x9E3779B97F4A7C15) & mask
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    return (z ^ (z >> 31)) & mask


def hash64_seeded(x, seed):
    mask = (1 << 64) - 1
    return hash64(x ^ ((seed * 0xA24BAED4963EE407) & mask))


# ---- per-app semantics (mirror rust/src/apps/*.rs exactly) -----------------

class App:
    name = None
    lane = None          # "f32" | "f64" | "u32" | "u64"
    reduce = None        # "sum" | "min" | "max"
    fixed_iters = None   # None = run to convergence

    def identity(self):
        if self.reduce == "sum":
            return {"f32": F32(0.0), "f64": F64(0.0)}.get(self.lane, 0)
        if self.reduce == "min":
            return {"f32": INF32, "f64": INF64,
                    "u32": (1 << 32) - 1, "u64": (1 << 64) - 1}[self.lane]
        return {"f32": -INF32, "f64": -INF64, "u32": 0, "u64": 0}[self.lane]

    def combine(self, a, b):
        if self.reduce == "sum":
            return a + b
        if self.reduce == "min":
            return min(a, b)
        return max(a, b)

    def changed(self, old, new):
        if self.lane in ("f32", "f64"):
            if np.isinf(old) and np.isinf(new):
                return False
            return new != old
        return new != old


class PageRank(App):
    name, lane, reduce, fixed_iters = "pagerank", "f32", "sum", 10
    damping = F32(0.85)

    def init(self, v):
        return F32(1.0) / F32(N)

    def gather(self, src, deg, w):
        if deg == 0:
            return F32(0.0)
        return F32(src / F32(deg))

    def apply(self, reduced, old):
        return F32((F32(1.0) - self.damping) / F32(N) + self.damping * F32(reduced))


class Sssp(App):
    name, lane, reduce, fixed_iters = "sssp", "f32", "min", None
    source = 0

    def init(self, v):
        return F32(0.0) if v == self.source else INF32

    def gather(self, src, deg, w):
        return F32(src + F32(1.0))

    def apply(self, reduced, old):
        return min(reduced, old)


class Bfs(Sssp):
    name = "bfs"


class Wcc(App):
    name, lane, reduce, fixed_iters = "wcc", "f32", "min", None

    def init(self, v):
        return F32(v)

    def gather(self, src, deg, w):
        return src

    def apply(self, reduced, old):
        return min(reduced, old)


class SpMv(App):
    name, lane, reduce, fixed_iters = "spmv", "f32", "sum", 1
    seed = 1

    def init(self, v):
        return F32(np.float32(hash64_seeded(v, self.seed) >> 40) / F32(1 << 24))

    def gather(self, src, deg, w):
        return src

    def apply(self, reduced, old):
        return reduced


class SpMv64(App):
    name, lane, reduce, fixed_iters = "spmv64", "f64", "sum", 1
    seed = 1

    def init(self, v):
        return F64(np.float64(hash64_seeded(v, self.seed) >> 40) / F64(1 << 24))

    def gather(self, src, deg, w):
        return src

    def apply(self, reduced, old):
        return reduced


class WeightedSssp(App):
    name, lane, reduce, fixed_iters = "wsssp", "f32", "min", None
    source = 0

    def init(self, v):
        return F32(0.0) if v == self.source else INF32

    def gather(self, src, deg, w):
        return F32(src + w)

    def apply(self, reduced, old):
        return min(reduced, old)


class LabelProp(App):
    name, lane, reduce, fixed_iters = "labelprop", "u64", "min", None

    def init(self, v):
        return v

    def gather(self, src, deg, w):
        return src

    def apply(self, reduced, old):
        return min(reduced, old)


class MaxDeg(App):
    name, lane, reduce, fixed_iters = "maxdeg", "u32", "max", None

    def init(self, v):
        return 0

    def gather(self, src, deg, w):
        return max(src, deg)

    def apply(self, reduced, old):
        return max(reduced, old)


APPS = [PageRank(), Sssp(), Wcc(), Bfs(), SpMv(), SpMv64(),
        WeightedSssp(), LabelProp(), MaxDeg()]


def run_reference(app, graph=None, start_vals=None):
    edges, weights = graph if graph is not None else fixture_graph()
    in_adj, in_w, out_deg = adjacency(edges, weights)
    vals = start_vals[:] if start_vals is not None else [app.init(v) for v in range(N)]
    iters = app.fixed_iters if app.fixed_iters is not None else MAX_ITERS
    for _ in range(iters):
        nxt = []
        for v in range(N):
            acc = app.identity()
            for u, w in zip(in_adj[v], in_w[v]):
                acc = app.combine(acc, app.gather(vals[u], out_deg[u], w))
            nxt.append(app.apply(acc, vals[v]))
        changed = any(app.changed(o, n) for o, n in zip(vals, nxt))
        vals = nxt
        if not changed:
            break
    if app.lane == "f32":
        assert all(isinstance(x, np.float32) for x in vals), app.name
    if app.lane == "f64":
        assert all(isinstance(x, np.float64) for x in vals), app.name
    return vals


# ---- dynamic-graph (delta-shard) semantics mirror ---------------------------
#
# Mirrors rust/src/graph/mutation.rs + storage/delta.rs closely enough for
# the no-toolchain container to verify the subsystem's two core theorems:
#
# 1. **Row-order equivalence** — merging base rows (survivors in base
#    order) with resident delta inserts (insertion order per destination)
#    yields exactly the per-row edge sequence of a from-scratch stable
#    counting sort over the final edge list.  This is what makes
#    delta-merged execution bit-identical to a rebuild in the engine.
# 2. **Monotone warm restart** — for Min/Max apps whose apply folds the old
#    value, iterating from the previous fixpoint after insert-only batches
#    reaches the same fixpoint as a cold start.
#
# Mutations: ("+", s, d, w) appends one edge; ("-", s, d) removes every
# live (s, d) edge (base via tombstone, prior inserts by pruning).

DELTA_BATCHES = [
    # batch 1: inserts + deletes, incl. insert-then-delete and reinsert
    [("+", 3, 11, np.float32(0.5)), ("-", 7, 5, None), ("+", 0, 12, np.float32(1.0)),
     ("-", 3, 11, None), ("+", 3, 11, np.float32(2.0))],
    # batch 2: deletes aimed at known base edges of the fixture graph
    [("-", 0, 5, None), ("+", 40, 1, np.float32(0.25)), ("+", 40, 2, np.float32(0.75))],
    # batch 3: insert-only (the incremental-restart epoch)
    [("+", 5, 30, np.float32(1.5)), ("+", 17, 44, np.float32(0.5)),
     ("+", 5, 31, np.float32(1.0))],
]


def apply_batch(edges, weights, batch):
    """The executable specification (mirrors mutation::apply_batch)."""
    for op in batch:
        if op[0] == "+":
            _, s, d, w = op
            edges.append((s, d))
            weights.append(w)
        else:
            _, s, d = op[0], op[1], op[2]
            keep = [k for k, e in enumerate(edges) if e != (s, d)]
            edges[:] = [edges[k] for k in keep]
            weights[:] = [weights[k] for k in keep]


def merged_rows(base_edges, base_weights, batches):
    """Per-destination rows via the delta-shard path: base survivors in
    base order + inserts in insertion order, tombstones kill base edges."""
    ins = [[] for _ in range(N)]     # per-destination (src, w), insertion order
    tombs = [set() for _ in range(N)]
    for batch in batches:
        for op in batch:
            if op[0] == "+":
                _, s, d, w = op
                ins[d].append((s, w))
            else:
                _, s, d = op[0], op[1], op[2]
                ins[d] = [(u, w) for (u, w) in ins[d] if u != s]
                tombs[d].add(s)
    rows = [[] for _ in range(N)]
    for (s, d), w in zip(base_edges, base_weights):
        if s not in tombs[d]:
            rows[d].append((s, w))
    for d in range(N):
        rows[d].extend(ins[d])
    return rows


def rebuild_rows(edges, weights):
    """Per-destination rows via a stable counting sort of the final list —
    what a from-scratch preprocess produces."""
    rows = [[] for _ in range(N)]
    for (s, d), w in zip(edges, weights):
        rows[d].append((s, w))
    return rows


def delta_selfcheck():
    base_edges, base_weights = fixture_graph()

    # theorem 1: delta-merged rows == rebuilt rows, edge for edge, in order
    final_edges = list(base_edges)
    final_weights = list(base_weights)
    for batch in DELTA_BATCHES:
        apply_batch(final_edges, final_weights, batch)
    merged = merged_rows(base_edges, base_weights, DELTA_BATCHES)
    rebuilt = rebuild_rows(final_edges, final_weights)
    assert merged == rebuilt, "delta merge order != stable rebuild order"
    assert sum(len(r) for r in merged) == len(final_edges)
    # the deletes actually fired (batch 2 targets live base edges)
    assert len(final_edges) < len(base_edges) + sum(
        1 for b in DELTA_BATCHES for op in b if op[0] == "+"
    )

    # theorem 2: monotone warm restart — old fixpoint + insert-only batch
    # re-converges to the cold fixpoint (Min/Max apps fold old in apply)
    pre_edges = list(base_edges)
    pre_weights = list(base_weights)
    for batch in DELTA_BATCHES[:2]:
        apply_batch(pre_edges, pre_weights, batch)
    post_edges = list(pre_edges)
    post_weights = list(pre_weights)
    apply_batch(post_edges, post_weights, DELTA_BATCHES[2])
    assert all(op[0] == "+" for op in DELTA_BATCHES[2]), "epoch 3 must be insert-only"
    for app in APPS:
        if app.reduce == "sum":
            continue
        old_fix = run_reference(app, graph=(pre_edges, pre_weights))
        cold = run_reference(app, graph=(post_edges, post_weights))
        warm = run_reference(app, graph=(post_edges, post_weights), start_vals=old_fix)
        assert warm == cold, f"{app.name}: warm restart missed the cold fixpoint"
    print("delta semantics mirror: ok "
          f"({len(DELTA_BATCHES)} batches, {len(final_edges)} final edges)")


def render(app, vals):
    lines = []
    for x in vals:
        if app.lane == "f32":
            bits = struct.unpack("<I", struct.pack("<f", float(x)))[0]
            lines.append(f"{bits:08x}")
        elif app.lane == "f64":
            bits = struct.unpack("<Q", struct.pack("<d", float(x)))[0]
            lines.append(f"{bits:016x}")
        else:
            lines.append(str(int(x)))
    return "\n".join(lines) + "\n"


def main():
    check = "--check" in sys.argv
    # the dynamic-graph semantics mirror runs in both modes: it is the
    # no-toolchain container's way to verify the Rust subsystem's ordering
    # and warm-restart theorems
    delta_selfcheck()
    root = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")
    root = os.path.normpath(root)
    os.makedirs(root, exist_ok=True)
    status = 0
    for app in APPS:
        body = render(app, run_reference(app))
        path = os.path.join(root, f"{app.name}.txt")
        if check:
            with open(path) as f:
                committed = f.read()
            if committed != body:
                print(f"MISMATCH: {path}")
                status = 1
            else:
                print(f"ok: {path}")
        else:
            with open(path, "w") as f:
                f.write(body)
            print(f"wrote {path}")
    sys.exit(status)


if __name__ == "__main__":
    main()
