//! Tiny property-testing harness (the offline crate set has no proptest).
//!
//! `check(seed, cases, |g| ...)` runs a closure against `cases` randomly
//! generated inputs drawn through the [`Gen`] handle; on failure it reports
//! the case seed so the exact input can be replayed deterministically.
//! Used by coordinator/engine invariant tests (routing, batching, state).

use crate::util::rng::Xoshiro256;

/// Random-input source handed to property bodies.
pub struct Gen {
    rng: Xoshiro256,
    pub case_seed: u64,
}

impl Gen {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.next_f32()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of length in `[min_len, max_len)` filled by `f`.
    pub fn vec<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = if min_len + 1 >= max_len { min_len } else { self.usize_in(min_len, max_len) };
        (0..n).map(|_| f(self)).collect()
    }

    /// Random edge list over `n` vertices with `m` edges.
    pub fn edges(&mut self, n: usize, m: usize) -> Vec<(u32, u32)> {
        (0..m)
            .map(|_| (self.usize_in(0, n) as u32, self.usize_in(0, n) as u32))
            .collect()
    }
}

/// Run `body` against `cases` random inputs.  Panics (with the replay seed)
/// on the first failing case.
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut body: F) {
    let mut meta = Xoshiro256::seed_from_u64(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen { rng: Xoshiro256::seed_from_u64(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property failed: top seed {seed}, case {case}, replay with case_seed {case_seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single case by its `case_seed` (printed on failure).
pub fn replay<F: FnMut(&mut Gen)>(case_seed: u64, mut body: F) {
    let mut g = Gen { rng: Xoshiro256::seed_from_u64(case_seed), case_seed };
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(1, 50, |_| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check(7, 3, |g| a.push(g.u64()));
        check(7, 3, |g| b.push(g.u64()));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check(2, 10, |g| assert!(g.usize_in(0, 10) < 5));
    }

    #[test]
    fn edges_in_bounds() {
        check(3, 20, |g| {
            let n = g.usize_in(1, 50);
            let edges = g.edges(n, 100);
            assert!(edges.iter().all(|&(u, v)| (u as usize) < n && (v as usize) < n));
        });
    }
}
