//! Human-readable formatting for byte counts, edge rates and durations —
//! used by the CLI, examples and bench reports.

use std::time::Duration;

/// `1536 -> "1.50 KiB"`, `0 -> "0 B"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// `1_500_000 -> "1.50M"`, plain counts.
pub fn count(n: u64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "B", "T"];
    if n < 1000 {
        return format!("{n}");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

/// Edges-per-second rate, the paper's Table I performance unit.
pub fn rate(edges: u64, dur: Duration) -> String {
    let secs = dur.as_secs_f64().max(1e-12);
    format!("{}/s", count((edges as f64 / secs) as u64))
}

/// `Duration` with adaptive units.
pub fn duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{:.0}m{:.1}s", (s / 60.0).floor(), s % 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_fmt() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(1023), "1023 B");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(1 << 30), "1.00 GiB");
    }

    #[test]
    fn count_fmt() {
        assert_eq!(count(999), "999");
        assert_eq!(count(1_500_000), "1.50M");
        assert_eq!(count(91_800_000_000), "91.80B");
    }

    #[test]
    fn duration_fmt() {
        assert_eq!(duration(Duration::from_secs(90)), "1m30.0s");
        assert_eq!(duration(Duration::from_millis(1500)), "1.50s");
        assert_eq!(duration(Duration::from_micros(250)), "250.00µs");
    }

    #[test]
    fn rate_fmt() {
        assert_eq!(rate(2_000_000, Duration::from_secs(2)), "1.00M/s");
    }
}
