//! Fast non-cryptographic hashing for the Bloom filters (§II-D.1).
//!
//! `hash64` is an xxHash64-style avalanche mix over a single `u64` key —
//! exactly what the Bloom filter needs (vertex ids are `u32`/`u64`).  The
//! double-hashing scheme `bloom_indexes` derives k bit positions from two
//! independent 64-bit halves (Kirsch–Mitzenmacher).

/// Strong 64-bit mix of a 64-bit key (finalizer from SplitMix64/xxh3).
#[inline]
pub fn hash64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Seeded variant: mixes the seed in before finalizing.
#[inline]
pub fn hash64_seeded(x: u64, seed: u64) -> u64 {
    hash64(x ^ seed.wrapping_mul(0xA24BAED4963EE407))
}

/// FNV-1a over bytes, for hashing small byte strings (file headers etc.).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The Kirsch–Mitzenmacher double-hashing basis `(h1, h2)` of a key: the
/// filter-independent part of a Bloom probe.  Hashing is the per-key cost;
/// deriving the `k` bit positions for a particular filter from `(h1, h2)`
/// is a handful of integer ops — so a key probed against many filters
/// should compute its basis once (see `bloom::digest`).
#[inline]
pub fn bloom_basis(key: u64) -> (u64, u64) {
    let h = hash64(key);
    let h1 = h & 0xFFFF_FFFF;
    let h2 = (h >> 32) | 1; // odd => full period mod powers of two
    (h1, h2)
}

/// Kirsch–Mitzenmacher double hashing: derive `k` indexes in `[0, m)` from
/// one 64-bit hash. `m` must be > 0.
#[inline]
pub fn bloom_indexes(key: u64, k: u32, m: u64, out: &mut [u64]) {
    debug_assert!(out.len() >= k as usize);
    let (h1, h2) = bloom_basis(key);
    for (i, slot) in out.iter_mut().enumerate().take(k as usize) {
        *slot = h1.wrapping_add(h2.wrapping_mul(i as u64)) % m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash64_is_deterministic_and_spreads() {
        assert_eq!(hash64(0), hash64(0));
        assert_ne!(hash64(0), hash64(1));
        // successive keys should differ in roughly half their bits
        let d = (hash64(100) ^ hash64(101)).count_ones();
        assert!((16..=48).contains(&d), "avalanche too weak: {d}");
    }

    #[test]
    fn seeded_differs_per_seed() {
        assert_ne!(hash64_seeded(42, 1), hash64_seeded(42, 2));
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("") = offset basis
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn bloom_indexes_in_range_and_distinctish() {
        let mut out = [0u64; 8];
        bloom_indexes(12345, 8, 1000, &mut out);
        assert!(out.iter().all(|&i| i < 1000));
        let mut uniq = out.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() >= 6, "mostly distinct: {uniq:?}");
    }

    #[test]
    fn bloom_basis_matches_bloom_indexes() {
        // the precomputed basis must derive exactly the bit positions the
        // one-shot path produces, for any (k, m)
        for key in [0u64, 1, 12345, u64::MAX] {
            let (h1, h2) = bloom_basis(key);
            assert_eq!(h2 & 1, 1, "h2 must be odd");
            for &(k, m) in &[(1u32, 64u64), (7, 1000), (16, 1 << 20)] {
                let mut out = [0u64; 16];
                bloom_indexes(key, k, m, &mut out);
                for (i, &want) in out.iter().enumerate().take(k as usize) {
                    assert_eq!(h1.wrapping_add(h2.wrapping_mul(i as u64)) % m, want);
                }
            }
        }
    }

    #[test]
    fn hash_distribution_chi_square_ish() {
        // 64 buckets, 64k keys: each bucket ~1024 ± a few sigma.
        let mut counts = [0u32; 64];
        for key in 0..65536u64 {
            counts[(hash64(key) % 64) as usize] += 1;
        }
        for &c in &counts {
            assert!((900..1150).contains(&c), "bucket skew: {c}");
        }
    }
}
