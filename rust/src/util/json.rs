//! Minimal JSON parser + writer (the offline crate set has no serde).
//!
//! Only what the repo needs: parsing `artifacts/manifest.json`, experiment
//! configs, and emitting report files.  Supports the full JSON value grammar
//! with the usual escapes; numbers are kept as `f64` plus an exact `i64`
//! fast path.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integers that fit exactly in i64 stay exact.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow!("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // copy raw utf-8 byte run
                    let start = self.pos - 1;
                    while let Some(&c) = self.b.get(self.pos) {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos])?);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| anyhow!("bad \\u"))?;
            self.pos += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

// ---- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Num(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_manifest_shape() {
        let text = r#"{
            "version": 1,
            "geometry": {"v_max": 2048, "e_max": 16384, "tile_e": 1024},
            "artifacts": {"pr_shard": {"file": "pr_shard.hlo.txt"}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("geometry").unwrap().req("v_max").unwrap().as_i64(), Some(2048));
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":true,"d":null}}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }
}
