//! Fixed-size bitset over `u64` words — backs the Bloom filter bit array and
//! the active-vertex tracking in the engine.

/// Dense bitset with `len` addressable bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// All-zero bitset with `len` bits.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Number of addressable bits.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear_bit(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i >> 6] >> (i & 63) & 1 == 1
    }

    /// Set all bits to zero (keeps capacity).
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Set all bits to one.
    pub fn set_all(&mut self) {
        self.words.fill(u64::MAX);
        self.trim_tail();
    }

    fn trim_tail(&mut self) {
        let tail = self.len & 63;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if any bit is set.
    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Iterator over the indexes of set bits, ascending.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let bit = w.trailing_zeros() as usize;
                w &= w - 1;
                Some(wi * 64 + bit)
            })
        })
    }

    /// In-place union. Panics if lengths differ.
    pub fn union_with(&mut self, other: &BitSet) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Raw words (for serialization).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words + length.
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert_eq!(words.len(), len.div_ceil(64));
        let mut s = Self { words, len };
        s.trim_tail();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129) && !b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.clear_bit(64);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn iter_ones_ascending() {
        let mut b = BitSet::new(200);
        for i in [3usize, 64, 65, 127, 128, 199] {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, vec![3, 64, 65, 127, 128, 199]);
    }

    #[test]
    fn set_all_respects_len() {
        let mut b = BitSet::new(70);
        b.set_all();
        assert_eq!(b.count_ones(), 70);
        assert!(b.get(69));
    }

    #[test]
    fn union() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        a.set(1);
        b.set(99);
        a.union_with(&b);
        assert!(a.get(1) && a.get(99));
        assert_eq!(a.count_ones(), 2);
    }

    #[test]
    fn words_roundtrip() {
        let mut a = BitSet::new(77);
        for i in (0..77).step_by(7) {
            a.set(i);
        }
        let b = BitSet::from_words(a.words().to_vec(), 77);
        assert_eq!(a, b);
    }
}
