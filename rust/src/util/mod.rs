//! Substrate utilities built from scratch (the image has no serde / rayon /
//! clap / criterion in its offline crate set, so this crate carries its own
//! minimal equivalents).

pub mod bench;
pub mod bitset;
pub mod hash;
pub mod humansize;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod varint;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
