//! Parallel-for substrate — the OpenMP replacement.
//!
//! The paper parallelizes shard processing with
//! `#pragma omp parallel for num_threads(N)` (Algorithm 1, line 3).  The
//! offline crate set has no rayon, so this module provides:
//!
//! * [`parallel_for`] — scoped, chunk-self-scheduling parallel loop
//!   (spawns per call; fine for coarse work).
//! * [`ThreadPool`] — persistent workers for the engine's per-iteration hot
//!   loop, avoiding thread spawn cost every iteration.
//!
//! Both use dynamic self-scheduling over an atomic cursor, which mirrors
//! OpenMP's `schedule(dynamic)` — important because shard processing times
//! vary wildly once selective scheduling starts skipping shards.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default (like OpenMP's
/// `OMP_NUM_THREADS` fallback): the machine's available parallelism.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n` on `threads` workers.  `f` must be
/// `Sync` (it is shared by reference), and items are claimed one at a time
/// from an atomic cursor (dynamic schedule, chunk = 1: shard-sized work
/// items are coarse enough that finer chunking is pure overhead).
pub fn parallel_for<F: Fn(usize) + Sync>(threads: usize, n: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like [`parallel_for`] but each worker owns a mutable slot of `state`,
/// enabling lock-free per-thread accumulators (`state.len()` must be >=
/// `threads`).  Worker `t` receives `(&mut state[t], item)` calls.
pub fn parallel_for_with<S: Send, F: Fn(&mut S, usize) + Sync>(
    threads: usize,
    n: usize,
    state: &mut [S],
    f: F,
) {
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n.min(state.len()));
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for slot in state.iter_mut().take(threads) {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(slot, i);
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    done: Mutex<usize>,
    cv: Condvar,
}

/// Persistent thread pool with a blocking `run_batch`.  Workers live for the
/// pool's lifetime; each `run_batch` dispatches one closure per worker and
/// waits for all of them — the engine uses it with an atomic item cursor to
/// get a pooled `parallel_for` without per-iteration spawns.
pub struct ThreadPool {
    tx: Vec<mpsc::Sender<Job>>,
    shared: Arc<PoolShared>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared { done: Mutex::new(0), cv: Condvar::new() });
        let mut tx = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (s, r) = mpsc::channel::<Job>();
            tx.push(s);
            let shared = shared.clone();
            handles.push(thread::spawn(move || {
                while let Ok(job) = r.recv() {
                    job();
                    let mut done = shared.done.lock().unwrap();
                    *done += 1;
                    shared.cv.notify_all();
                }
            }));
        }
        Self { tx, shared, handles }
    }

    pub fn threads(&self) -> usize {
        self.tx.len()
    }

    /// Run `f(i)` for `i in 0..n` across the pool's workers (dynamic
    /// self-scheduling).  Blocks until every item is processed.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let workers = self.tx.len().min(n);
        // SAFETY-free trick: we hand each worker an Arc'd closure over a
        // scoped borrow by boxing a 'static shim around raw pointers would be
        // unsound; instead we copy the borrow into an Arc<dyn Fn> via a
        // transmute-free channel: wrap in Arc and extend lifetime through a
        // blocking join below. We guarantee the borrow outlives the batch by
        // waiting on the done-counter before returning.
        let cursor = Arc::new(AtomicUsize::new(0));
        {
            let mut done = self.shared.done.lock().unwrap();
            *done = 0;
        }
        // Extend the lifetime of `f` to 'static for the duration of the
        // batch. Sound because `parallel_for` blocks until all workers have
        // finished running the closure (done-counter wait below), so the
        // reference never outlives the borrow.
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        for t in 0..workers {
            let cursor = cursor.clone();
            let job: Job = Box::new(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f_static(i);
            });
            self.tx[t].send(job).expect("worker alive");
        }
        let mut done = self.shared.done.lock().unwrap();
        while *done < workers {
            done = self.shared.cv.wait(done).unwrap();
        }
    }

    /// Run `f(&mut state[t], t)` once on **every** worker `t`, blocking
    /// until all return.  This is the substrate for worker-owned scratch
    /// arenas: each worker gets exclusive `&mut` access to its own state
    /// slot for the whole batch (no locks), and the slots persist across
    /// batches so per-iteration buffers are allocated once and reused.
    /// `state.len()` must be >= [`Self::threads`].
    pub fn broadcast_with<S: Send, F: Fn(&mut S, usize) + Sync>(&self, state: &mut [S], f: F) {
        let workers = self.tx.len();
        assert!(state.len() >= workers, "one state slot per worker required");
        {
            let mut done = self.shared.done.lock().unwrap();
            *done = 0;
        }
        // Lifetime extension with the same soundness argument as
        // `parallel_for`: the done-counter wait below keeps `f` and
        // `state` borrowed past every worker's last use.  Slots are
        // disjoint (`t`-indexed), so handing each worker a raw pointer to
        // its own element upholds &mut exclusivity.
        let f_ref: &(dyn Fn(&mut S, usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(&mut S, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let base = state.as_mut_ptr() as usize;
        for t in 0..workers {
            let job: Job = Box::new(move || {
                // SAFETY: slot `t` is touched by worker `t` alone, and the
                // batch-blocking wait keeps the borrow alive.
                let slot = unsafe { &mut *(base as *mut S).add(t) };
                f_static(slot, t);
            });
            self.tx[t].send(job).expect("worker alive");
        }
        let mut done = self.shared.done.lock().unwrap();
        while *done < workers {
            done = self.shared.cv.wait(done).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.clear(); // close channels => workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_items_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(4, 0, |_| panic!("no items"));
        let sum = AtomicU64::new(0);
        parallel_for(4, 1, |i| {
            sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_for_with_thread_state() {
        let n = 1000;
        let mut sums = vec![0u64; 4];
        parallel_for_with(4, n, &mut sums, |acc, i| {
            *acc += i as u64;
        });
        let total: u64 = sums.iter().sum();
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn pool_runs_batches_repeatedly() {
        let pool = ThreadPool::new(4);
        for round in 0..5 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(100, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 4950, "round {round}");
        }
    }

    #[test]
    fn broadcast_with_gives_every_worker_its_own_state() {
        let pool = ThreadPool::new(4);
        let mut scratch: Vec<Vec<u64>> = vec![Vec::new(); 4];
        for round in 0..3u64 {
            let cursor = AtomicUsize::new(0);
            pool.broadcast_with(&mut scratch, |s, t| {
                s.push(t as u64 + round * 10);
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= 100 {
                        break;
                    }
                }
            });
        }
        // every worker ran every round, into its own slot, which persisted
        for (t, s) in scratch.iter().enumerate() {
            assert_eq!(s.as_slice(), &[t as u64, t as u64 + 10, t as u64 + 20]);
        }
    }

    #[test]
    fn pool_more_items_than_threads() {
        let pool = ThreadPool::new(2);
        let hits: Vec<AtomicUsize> = (0..5000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(5000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
