//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`; graph generation and property tests
//! need a fast, seedable, reproducible generator, so we carry SplitMix64
//! (for seeding) and xoshiro256++ (the workhorse).  Both are public-domain
//! algorithms (Blackman & Vigna).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that low-entropy seeds (0, 1, 2…) still yield
    /// well-distributed state.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b), "all buckets hit");
    }

    #[test]
    fn gen_range_mean_is_uniformish() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| r.gen_range(1000)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 499.5).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }
}
