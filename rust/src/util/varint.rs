//! LEB128 variable-length integers + zigzag, used by the delta-varint shard
//! codec (`cache::deltavarint`) and the compact on-disk formats.

/// Append `v` as unsigned LEB128 to `out`. Returns bytes written (1..=10).
#[inline]
pub fn write_u64(out: &mut Vec<u8>, mut v: u64) -> usize {
    let mut n = 0;
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        n += 1;
        if v == 0 {
            out.push(byte);
            return n;
        }
        out.push(byte | 0x80);
    }
}

/// Read an unsigned LEB128 from `buf[pos..]`. Returns `(value, new_pos)`.
#[inline]
pub fn read_u64(buf: &[u8], mut pos: usize) -> Option<(u64, usize)> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let &byte = buf.get(pos)?;
        pos += 1;
        if shift == 63 && byte > 1 {
            return None; // overflow
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some((v, pos));
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

/// Zigzag encode: maps signed to unsigned preserving small magnitudes.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Zigzag decode.
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append `v` as zigzag LEB128.
#[inline]
pub fn write_i64(out: &mut Vec<u8>, v: i64) -> usize {
    write_u64(out, zigzag(v))
}

/// Read a zigzag LEB128.
#[inline]
pub fn read_i64(buf: &[u8], pos: usize) -> Option<(i64, usize)> {
    read_u64(buf, pos).map(|(v, p)| (unzigzag(v), p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn roundtrip_u64_edges() {
        for v in [0u64, 1, 127, 128, 255, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            let (got, pos) = read_u64(&buf, 0).unwrap();
            assert_eq!(got, v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn roundtrip_i64_edges() {
        for v in [0i64, 1, -1, 63, -64, i32::MIN as i64, i64::MAX, i64::MIN] {
            let mut buf = Vec::new();
            write_i64(&mut buf, v);
            let (got, pos) = read_i64(&buf, 0).unwrap();
            assert_eq!(got, v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn size_is_minimal() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 127);
        assert_eq!(buf.len(), 1);
        buf.clear();
        write_u64(&mut buf, 128);
        assert_eq!(buf.len(), 2);
        buf.clear();
        write_u64(&mut buf, u64::MAX);
        assert_eq!(buf.len(), 10);
    }

    #[test]
    fn truncated_input_is_none() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX);
        for cut in 0..buf.len() {
            assert!(read_u64(&buf[..cut], 0).is_none());
        }
    }

    #[test]
    fn zigzag_small_magnitudes_are_small() {
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
        for v in -1000i64..1000 {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn prop_roundtrip_stream() {
        // property: any sequence of u64s round-trips through a single buffer
        let mut rng = Xoshiro256::seed_from_u64(99);
        for _ in 0..100 {
            let n = rng.range_usize(1, 64);
            let vals: Vec<u64> = (0..n)
                .map(|_| rng.next_u64() >> rng.gen_range(64))
                .collect();
            let mut buf = Vec::new();
            for &v in &vals {
                write_u64(&mut buf, v);
            }
            let mut pos = 0;
            for &v in &vals {
                let (got, p) = read_u64(&buf, pos).unwrap();
                assert_eq!(got, v);
                pos = p;
            }
            assert_eq!(pos, buf.len());
        }
    }
}
