//! Micro-benchmark harness (the offline crate set has no criterion).
//!
//! Provides warmup + repeated timed runs with median/mean/p95 statistics and
//! a table printer used by every `rust/benches/*.rs` target (all declared
//! with `harness = false`).  Deliberately simple: wall-clock `Instant`,
//! black-box via `std::hint::black_box`, no outlier rejection beyond the
//! median.

use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Statistics over a set of timed samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<Duration>,
}

impl Stats {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn median(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().copied().min().unwrap_or_default()
    }

    pub fn max(&self) -> Duration {
        self.samples.iter().copied().max().unwrap_or_default()
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Relative std-dev (coefficient of variation) in percent.
    pub fn cv_percent(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let mean = self.mean().as_secs_f64();
        if mean == 0.0 {
            return 0.0;
        }
        let var = self
            .samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean;
                x * x
            })
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        100.0 * var.sqrt() / mean
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 2, iters: 7 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 3 }
    }

    /// Time `f` `iters` times after `warmup` unmeasured runs.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let samples = (0..self.iters.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect();
        Stats { samples }
    }
}

/// Fixed-width table printer for paper-style result rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Render as markdown (for EXPERIMENTS.md capture).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n| {} |\n|{}|\n",
            self.title,
            self.headers.join(" | "),
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats {
            samples: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
            ],
        };
        assert_eq!(s.mean(), Duration::from_millis(20));
        assert_eq!(s.median(), Duration::from_millis(20));
        assert_eq!(s.min(), Duration::from_millis(10));
        assert_eq!(s.max(), Duration::from_millis(30));
    }

    #[test]
    fn bench_runs_expected_count() {
        let mut n = 0;
        let b = Bench { warmup: 2, iters: 5 };
        let stats = b.run(|| n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.samples.len(), 5);
    }

    #[test]
    fn percentile_bounds() {
        let s = Stats {
            samples: (1..=100).map(Duration::from_millis).collect(),
        };
        assert_eq!(s.percentile(0.0), Duration::from_millis(1));
        assert_eq!(s.percentile(100.0), Duration::from_millis(100));
        assert!(s.percentile(95.0) >= Duration::from_millis(90));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "xx".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | xx |"));
    }

    #[test]
    fn cv_zero_for_identical() {
        let s = Stats { samples: vec![Duration::from_millis(5); 4] };
        assert!(s.cv_percent() < 1e-9);
    }
}
