//! `graphmp` — the CLI / leader entrypoint.
//!
//! ```text
//! graphmp generate   --dataset twitter-s --out edges.bin
//! graphmp preprocess --input edges.bin --vertices 32768 --out data.gmp [--symmetrize]
//! graphmp run        --data data.gmp --app pagerank [--iters 10]
//!                    [--engine native|xla] [--artifacts artifacts]
//!                    [--cache mode-2|none|...] [--no-cache] [--no-selective]
//!                    [--threads N] [--prefetch-depth N] [--throttle-mbps 300]
//! graphmp partrun    --data data.gmp --app pagerank --workers 4
//!                    [--split 2,5] [engine flags as for run]
//! graphmp baseline   --system psw|esg|dsw|vsp|inmem --data edges.bin
//!                    --vertices N --app pagerank [--iters 10]
//! graphmp info       --data data.gmp
//! graphmp datasets
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use graphmp::apps;
use graphmp::baselines;
use graphmp::cache::Codec;
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::{Dataset, DATASETS};
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::graph::edgelist;
use graphmp::runtime::ShardRuntime;
use graphmp::sharding::PreprocessConfig;
use graphmp::storage::{io, DatasetDir};
use graphmp::util::humansize;

const BOOL_FLAGS: &[&str] = &[
    "no-cache",
    "no-selective",
    "symmetrize",
    "streaming",
    "quick",
    "help",
    "adaptive",
    "weighted",
    "no-stream-gather",
    "incremental",
    "save-values",
    "all",
    "direct-io",
    "no-simd",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, BOOL_FLAGS)?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&args),
        "preprocess" => cmd_preprocess(&args),
        "run" => cmd_run(&args),
        "partrun" => cmd_partrun(&args),
        "partworker" => cmd_partworker(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "watch" => cmd_watch(&args),
        "ingest" => cmd_ingest(&args),
        "compact" => cmd_compact(&args),
        "mutate-gen" => cmd_mutate_gen(&args),
        "baseline" => cmd_baseline(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "info" => cmd_info(&args),
        "top" => cmd_top(&args),
        "trace-dump" => cmd_trace_dump(&args),
        "datasets" => cmd_datasets(),
        "apps" => cmd_apps(),
        _ => {
            print!("{}", help());
            Ok(())
        }
    }
}

/// Usage text; the app list is derived from `apps::REGISTRY` so it can
/// never drift from `by_name`.
fn help() -> String {
    format!(
        r#"graphmp — semi-external-memory graph processing (GraphMP reproduction)

USAGE:
  graphmp generate   --dataset <name> --out <file> [--weighted]
  graphmp preprocess --input <edges> --vertices <N> --out <dir> [--symmetrize]
                     (a weighted input's weight lane is carried into the shards)
  graphmp run        --data <dir> --app <{apps}>
                     [--iters N] [--engine native|xla] [--artifacts <dir>]
                     [--cache <none|snaplite|zlib-1|zlib-3|zstd-1|delta-varint>]
                     [--no-cache] [--no-selective] [--threads N]
                     [--prefetch-depth N]   shards the I/O pipeline decodes
                                            ahead of compute (0 = synchronous)
                     [--adaptive]           let the I/O governor size the
                                            window, order shards hottest-
                                            first and loan spare cache budget
                     [--prefetch-max N]     adaptive window ceiling (def. 8)
                     [--no-stream-gather]   decode compressed cache hits to a
                                            CSR instead of streaming them into
                                            the gather (the ablation path)
                     [--chunk-rows N]       rows per intra-shard work chunk
                                            (def. 8192; 0 = never split)
                     [--direct-io]          read shards via the O_DIRECT
                                            submission ring (io_uring where
                                            the kernel has it, an aligned
                                            thread pool elsewhere); the
                                            governor window sets the device
                                            queue depth.  GRAPHMP_DIRECT_IO=1
                                            flips the default on,
                                            GRAPHMP_URING=pool pins the
                                            fallback ring
                     [--no-simd]            pin the scalar gather fold
                                            (results are bit-identical either
                                            way; GRAPHMP_SIMD=0 equivalent)
                     [--epoch N]            open a historical snapshot epoch
                                            (default: the latest)
                     [--trace <file>]       flight recorder: append GMTF span
                                            records (per-iteration + sampled
                                            per-shard acquire/decode/fold
                                            timings) to <file>, ring-capped;
                                            read back with `trace-dump`
                     [--trace-cap N]        ring capacity in records (def. 4096)
                     [--trace-sample N]     span every Nth shard (def. 16;
                                            0 = iteration records only)
                     [--save-values]        persist the fixpoint (epoch-
                                            tagged) for incremental restart
                     [--incremental]        warm-start from saved values;
                                            monotone (Min/Max) apps with an
                                            insert-only history re-converge
                                            from the prior fixpoint, anything
                                            else falls back to a cold start
                     [--dump-values <file>] write the result values as text
                                            (bit-exact, one per line)
                     [--throttle-mbps N]
  graphmp partrun    --data <dir> --app <name> [--workers N]
                     [--split <b1,b2,...>] [--dump-values <file>]
                     [engine flags as for `run`, forwarded to every worker]
                     (partitioned execution: N worker processes, each
                      owning a contiguous shard run, driven through
                      iteration barriers over Unix sockets; only *changed*
                      vertex values and frontier bits cross a barrier.
                      Results are bit-identical to `run` for every app,
                      worker count and split — `--dump-values` output
                      `cmp`s clean against a single-process dump.
                      --split gives explicit interior shard boundaries,
                      e.g. `2,5` over 8 shards makes parts 0..2, 2..5,
                      5..8; otherwise shards split evenly over --workers
                      (default 2).  Unix only)
  graphmp serve      --listen 127.0.0.1:0 [--socket <path>] [--data <dir>]
                     [--max-heavy 2] [--max-light 32] [--max-queue 16]
                     [--session-ttl-secs 3600]  evict sessions idle this
                                                long (0 = never); any
                                                request on a session
                                                counts as use
                     [--engine-ttl-secs N]  evict resident engines idle this
                                            long (0 = never, the default);
                                            an engine pinned by an open
                                            session or an in-flight run is
                                            never evicted
                     [--metrics-listen <addr>]  also serve Prometheus text
                                            over plain HTTP (`GET /metrics`);
                                            prints `metrics-listening <addr>`
                                            when bound.  The same text is
                                            always available as the `metrics`
                                            protocol verb
                     [--trace <file>]       flight recorder, as for `run`
                     [engine flags as for `run`]
                     (resident daemon: keeps one engine per dataset loaded
                      and serves epoch-pinned sessions over a line protocol;
                      prints `listening <addr>` when ready.  `ingest`
                      requests advance the dataset while open sessions keep
                      reading their snapshot bit-identically)
  graphmp client     --connect <addr> | --socket <path>  <request ...>
                     [--dump-values <file>]
                     (send one request line, e.g. `ping`, `open data=<dir>`,
                      `run session=1 app=pagerank values=1`,
                      `value session=1 app=pagerank vertex=7`,
                      `ingest data=<dir> batch=<file>`,
                      `watch data=<dir> app=<name> [window=N]`,
                      `poll data=<dir> app=<name>`, `shutdown`;
                      --dump-values writes payload lines bit-identical to
                      `run --dump-values`)
  graphmp watch      --data <dir> --app <name> [--window N]
                     [--dump-changed <file>] [engine flags as for `run`]
                     (standing query: the first call computes the fixpoint
                      and emits every vertex as `<vertex> <bits>`; every
                      later call advances past any ingests since and emits
                      ONLY the changed lines — monotone apps warm-restart
                      (deletes re-derive the affected closure), single-pass
                      Sum apps refold just the mutated rows, both bit-
                      identical to a cold recompute.  --window N ages the
                      oldest ingest batch out once more than N are live,
                      by replaying its inserts as deletes.  State lives in
                      watch_<app>.gmw next to the dataset; the daemon's
                      `watch`/`poll` verbs advance the same file)
  graphmp ingest     --data <dir> --batch <file.gmdl|file.txt>
                     [--bloom-fpr 0.01]
                     (apply one mutation batch: `+ src dst [w]` inserts,
                      `- src dst` tombstone deletes; creates a new epoch —
                      base shards are never rewritten)
  graphmp compact    --data <dir> [--min-ratio 0.2] [--all]
                     (rewrite merged shard files for every shard whose
                      delta/base edge ratio reaches the threshold; results
                      are bit-identical, old epochs stay reproducible)
  graphmp mutate-gen --data <dir> --count <N> --out <file>
                     [--seed 1] [--delete-fraction 0.2] [--weighted]
                     (deterministic synthetic batch; deletes aim at live
                      edges so tombstones actually fire)
  graphmp baseline   --system <psw|esg|dsw|vsp|inmem> --data <edges>
                     --vertices <N> --app <name> [--iters N]
  graphmp bench-compare --baseline <BENCH_baseline.json> --current <BENCH_pr.json>
                     [--tolerance 0.25] [--min-abs-secs 0.25]
                     [--markdown <file>]  append the delta table as a GFM
                                          table (CI points this at
                                          $GITHUB_STEP_SUMMARY)
                     (exit 1 when any bench regressed past the gate)
  graphmp info       --data <dir>
  graphmp top        <addr> [--interval-ms 1000] [--iters N]
                     (live daemon dashboard: polls the `metrics` verb and
                      renders one line per dataset — epoch, iterations,
                      cache hit %, io-wait fraction, window, resident
                      bytes — plus a daemon summary line.  --iters 0
                      (default) refreshes until interrupted)
  graphmp trace-dump <file.gmtf>
                     (render a `--trace` flight-recorder log as text:
                      one line per meta/iter/shard record)
  graphmp datasets
  graphmp apps       (list every vertex program with its value lane)

Observability: every command honours GRAPHMP_OBS=0 (drop all metric and
trace updates); the daemon exposes Prometheus text via the `metrics` verb
(`graphmp client --connect <addr> metrics`) and `--metrics-listen`.
"#,
        apps = apps::app_names()
    )
}

fn cmd_apps() -> Result<()> {
    println!("{:<12} {:<6} {:<20} about", "name", "lane", "aliases");
    for entry in apps::REGISTRY {
        println!(
            "{:<12} {:<6} {:<20} {}",
            entry.name,
            entry.lane.name(),
            entry.aliases.join(","),
            entry.about
        );
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let name = args.req("dataset")?;
    let out = PathBuf::from(args.req("out")?);
    let d = Dataset::by_name(name)?;
    eprintln!(
        "generating {} (stands in for {}): |V|={} |E|={}{}",
        d.name,
        d.stands_in_for,
        humansize::count(d.num_vertices() as u64),
        humansize::count(d.num_edges),
        if args.has("weighted") { " [weighted]" } else { "" }
    );
    let edges = d.generate();
    if args.has("weighted") {
        let weights = graphmp::graph::generator::synth_weights(
            &edges,
            graphmp::coordinator::experiment::WEIGHT_SEED,
        );
        edgelist::write_binary_weighted(&out, &edges, &weights)?;
    } else {
        edgelist::write_binary(&out, &edges)?;
    }
    eprintln!("wrote {}", out.display());
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("input")?);
    let out = DatasetDir::new(args.req("out")?);
    // --streaming: the external-memory two-pass pipeline (binary input only,
    // no --symmetrize) for graphs larger than RAM
    if args.has("streaming") {
        anyhow::ensure!(
            !args.has("symmetrize"),
            "--streaming and --symmetrize are mutually exclusive"
        );
        let vertices = args.get_usize("vertices", 0)?;
        anyhow::ensure!(vertices > 0, "--streaming requires --vertices");
        let cfg = PreprocessConfig {
            max_edges_per_shard: args.get_usize(
                "max-edges-per-shard",
                PreprocessConfig::default().max_edges_per_shard,
            )?,
            bloom_fpr: args.get_f64("bloom-fpr", 0.01)?,
        };
        let t0 = std::time::Instant::now();
        let res = graphmp::sharding::preprocess_streaming(
            input.file_stem().and_then(|s| s.to_str()).unwrap_or("graph"),
            &input,
            vertices,
            &out,
            &cfg,
        )?;
        eprintln!(
            "preprocessed (streaming): |V|={} |E|={} shards={} in {}",
            res.property.info.num_vertices,
            res.property.info.num_edges,
            res.property.num_shards(),
            humansize::duration(t0.elapsed())
        );
        return Ok(());
    }
    let (mut edges, mut weights) = edgelist::read_auto_weighted(&input)?;
    if args.has("symmetrize") {
        let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
        edges.extend(rev);
        let wrev = weights.clone();
        weights.extend(wrev);
    }
    let max_id = edges.iter().map(|&(s, d)| s.max(d)).max().unwrap_or(0) as usize;
    let vertices = args.get_usize("vertices", max_id + 1)?;
    let cfg = PreprocessConfig {
        max_edges_per_shard: args
            .get_usize("max-edges-per-shard", PreprocessConfig::default().max_edges_per_shard)?,
        bloom_fpr: args.get_f64("bloom-fpr", 0.01)?,
    };
    let t0 = std::time::Instant::now();
    let res = graphmp::sharding::preprocess_weighted(
        input.file_stem().and_then(|s| s.to_str()).unwrap_or("graph"),
        &edges,
        &weights,
        vertices,
        &out,
        &cfg,
    )?;
    eprintln!(
        "preprocessed{}: |V|={} |E|={} shards={} bloom={} in {}",
        if weights.is_empty() { "" } else { " (weighted)" },
        res.property.info.num_vertices,
        res.property.info.num_edges,
        res.property.num_shards(),
        humansize::bytes(res.bloom_bytes),
        humansize::duration(t0.elapsed())
    );
    Ok(())
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig {
        max_iters: args.get_usize("iters", 0)?,
        selective: !args.has("no-selective"),
        convergence_tol: args.get_f64("tol", 0.0)? as f32,
        ..Default::default()
    };
    if let Some(t) = args.get("threads") {
        cfg.threads = t.parse().context("--threads")?;
    }
    cfg.prefetch_depth =
        args.get_usize("prefetch-depth", EngineConfig::default().prefetch_depth)?;
    cfg.adaptive = args.has("adaptive");
    cfg.prefetch_max = args.get_usize("prefetch-max", EngineConfig::default().prefetch_max)?;
    cfg.stream_gather = !args.has("no-stream-gather");
    cfg.chunk_rows = args.get_usize("chunk-rows", EngineConfig::default().chunk_rows)?;
    if args.has("direct-io") {
        cfg.direct_io = true;
    }
    if args.has("no-simd") {
        cfg.simd = false;
    }
    if let Some(e) = args.get("epoch") {
        cfg.epoch = Some(e.parse().context("--epoch")?);
    }
    if args.has("no-cache") {
        cfg.cache_budget = 0;
    } else if let Some(c) = args.get("cache") {
        cfg.cache_codec = c.parse::<Codec>()?;
    }
    if let Some(b) = args.get("cache-budget-mb") {
        cfg.cache_budget = b.parse::<usize>().context("--cache-budget-mb")? << 20;
    }
    match args.get_or("engine", "native") {
        "native" => {}
        "xla" => {
            let adir = PathBuf::from(args.get_or("artifacts", "artifacts"));
            let rt = ShardRuntime::load(&adir)
                .context("loading AOT artifacts (run `make artifacts`)")?;
            cfg.backend = Backend::Xla(Arc::new(rt));
        }
        other => bail!("unknown engine {other:?} (native|xla)"),
    }
    Ok(cfg)
}

/// Install the flight recorder when `--trace <path>` was given; returns
/// whether it was.  The caller pairs this with [`finish_trace`] once the
/// run is over (the daemon leaves it installed for its lifetime instead).
fn install_trace(args: &Args) -> Result<bool> {
    let Some(path) = args.get("trace") else { return Ok(false) };
    let cap = args.get_usize("trace-cap", 0)?;
    let sample = args.get_usize("trace-sample", graphmp::obs::trace::DEFAULT_SAMPLE as usize)?;
    graphmp::obs::trace::install(std::path::Path::new(path), cap, sample as u32)?;
    Ok(true)
}

fn finish_trace() {
    if let Some(path) = graphmp::obs::trace::finish() {
        eprintln!("trace written -> {}", path.display());
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let app = apps::by_name(args.req("app")?)?;
    if let Some(mbps) = args.get("throttle-mbps") {
        io::set_throttle(mbps.parse::<u64>().context("--throttle-mbps")? << 20);
    }
    install_trace(args)?;
    let cfg = engine_config(args)?;
    let engine_name = cfg.backend.name();
    let engine = VswEngine::open(data.clone(), cfg)?;
    let property = engine.property();
    eprintln!(
        "loaded {}: |V|={} |E|={} shards={} epoch={} (load {})",
        property.name,
        humansize::count(property.info.num_vertices),
        humansize::count(property.info.num_edges),
        property.num_shards(),
        engine.epoch(),
        humansize::duration(engine.load_wall)
    );
    let result = if args.has("incremental") {
        run_incremental(&engine, &app, &data)?
    } else {
        engine.run_any(&app)?
    };
    if args.has("save-values") {
        let path = data.values_path(app.name());
        graphmp::storage::delta::save_values(&path, engine.epoch(), &result.values)?;
        eprintln!(
            "saved {} fixpoint at epoch {} -> {}",
            app.name(),
            engine.epoch(),
            path.display()
        );
    }
    if let Some(out) = args.get("dump-values") {
        std::fs::write(out, render_values(&result.values))
            .with_context(|| format!("writing {out}"))?;
        eprintln!("dumped {} values -> {out}", result.values.len());
    }
    let s = &result.stats;
    println!(
        "app={} lane={} engine={} iters={} total={} rate={} mem={}",
        app.name(),
        app.lane().name(),
        engine_name,
        s.num_iters(),
        humansize::duration(s.total_wall),
        humansize::rate(s.edges_processed, s.total_wall),
        humansize::bytes(s.memory_bytes),
    );
    for it in &s.iters {
        println!(
            "  iter {:3}: {:>9}  io_wait={:>9} compute={:>9} decode={:>9} window={:2} processed={:3} skipped={:3} active={:8} ({:.4}%) read={} hits={} {}",
            it.iter,
            humansize::duration(it.wall),
            humansize::duration(it.io_wait),
            humansize::duration(it.compute),
            humansize::duration(std::time::Duration::from_nanos(it.decode_ns)),
            it.prefetch_depth,
            it.shards_processed,
            it.shards_skipped,
            it.active_vertices,
            it.active_ratio * 100.0,
            humansize::bytes(it.io.bytes_read),
            it.cache_hits,
            if it.selective_enabled { "[selective]" } else { "" },
        );
    }
    finish_trace();
    io::set_throttle(0);
    Ok(())
}

/// Bit-exact text rendering of a value array (one line per vertex; float
/// lanes as IEEE bit patterns) — what `--dump-values` writes, so CI can
/// `cmp` two runs for exact equality.  The serve protocol renders values
/// through the same [`graphmp::graph::AnyValues::render_bits_all`], so a
/// daemon response compares byte for byte against a dump file.
fn render_values(vals: &graphmp::graph::AnyValues) -> String {
    vals.render_bits_all()
}

/// The engine flags `partrun` forwards verbatim to every `partworker`
/// child, so the workers fold with the exact configuration the user gave
/// the coordinator.  `--engine`/`--artifacts` are deliberately absent:
/// partitioned execution is native-engine only (checked in
/// [`cmd_partrun`]), and `--dump-values`/`--workers`/`--split` are
/// coordinator-side concerns.
fn engine_forward_flags(args: &Args) -> Vec<String> {
    let mut fwd = Vec::new();
    for key in [
        "iters",
        "tol",
        "threads",
        "prefetch-depth",
        "prefetch-max",
        "chunk-rows",
        "epoch",
        "cache",
        "cache-budget-mb",
    ] {
        if let Some(v) = args.get(key) {
            fwd.push(format!("--{key}"));
            fwd.push(v.to_string());
        }
    }
    for key in ["no-selective", "adaptive", "no-stream-gather", "direct-io", "no-simd", "no-cache"]
    {
        if args.has(key) {
            fwd.push(format!("--{key}"));
        }
    }
    fwd
}

/// `graphmp partrun`: partitioned VSW.  Spawns one `partworker` process
/// per manifest part, drives them through iteration barriers, and stitches
/// the final values — bit-identical to `graphmp run` by construction (the
/// workers run the engine's own fold path; see [`graphmp::cluster`]).
#[cfg(unix)]
fn cmd_partrun(args: &Args) -> Result<()> {
    use graphmp::cluster::{coordinator::process::ProcessWorkers, Coordinator, PartitionManifest};
    use graphmp::storage::property::Property;

    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let app = apps::by_name(args.req("app")?)?;
    let cfg = engine_config(args)?;
    anyhow::ensure!(
        matches!(cfg.backend, Backend::Native),
        "partrun is native-engine only (every worker would need its own artifacts)"
    );
    // the shard count is epoch-stable (growth epochs extend shards in
    // place), so the base property is enough to build the manifest before
    // any worker exists
    let property = Property::load(&data.property_path())?;
    let num_shards = property.num_shards();
    let manifest = match args.get("split") {
        Some(spec) => {
            let m = PartitionManifest::parse_split(num_shards, spec)?;
            if let Some(w) = args.get("workers") {
                let w: usize = w.parse().context("--workers")?;
                anyhow::ensure!(
                    w == m.num_parts(),
                    "--split makes {} parts but --workers says {w}",
                    m.num_parts()
                );
            }
            m
        }
        None => PartitionManifest::balanced(num_shards, args.get_usize("workers", 2)?)?,
    };
    eprintln!(
        "partitioning {}: |V|={} |E|={} shards={} workers={} parts={}",
        property.name,
        humansize::count(property.info.num_vertices),
        humansize::count(property.info.num_edges),
        num_shards,
        manifest.num_parts(),
        manifest.to_json()
    );

    install_trace(args)?;
    let exe = std::env::current_exe().context("locating the graphmp binary")?;
    let forward = engine_forward_flags(args);
    let (workers, links) = ProcessWorkers::spawn(
        &exe,
        &data.root,
        &manifest,
        &forward,
        std::time::Duration::from_secs(120),
    )?;
    let mut coord = Coordinator::new(manifest, links)?;
    let dump = args.get("dump-values");
    let summary = coord.run(app.name(), cfg.max_iters, dump.is_some())?;
    drop(workers); // children already got part-shutdown; this reaps them

    if let Some(out) = dump {
        let mut text = String::with_capacity(summary.values.len() * 9);
        for line in &summary.values {
            text.push_str(line);
            text.push('\n');
        }
        std::fs::write(out, text).with_context(|| format!("writing {out}"))?;
        eprintln!("dumped {} values -> {out}", summary.values.len());
    }
    finish_trace();
    println!(
        "app={} lane={} engine=partitioned workers={} epoch={} iters={} total={} stitch={}",
        summary.app,
        summary.lane,
        summary.workers,
        summary.epoch,
        summary.iters.len(),
        humansize::duration(summary.total_wall),
        humansize::bytes(summary.stitch_bytes),
    );
    for it in &summary.iters {
        println!(
            "  iter {:3}: {:>9}  processed={:3} skipped={:3} active={:8} delta-lines={:8} edges={}",
            it.iter,
            humansize::duration(it.wall),
            it.shards_processed,
            it.shards_skipped,
            it.active,
            it.delta_lines,
            humansize::count(it.edges),
        );
    }
    Ok(())
}

#[cfg(not(unix))]
fn cmd_partrun(_args: &Args) -> Result<()> {
    bail!("partrun is only available on unix (worker links ride Unix-domain sockets)")
}

/// The hidden `partworker` subcommand: one partition worker process.
/// Spawned by `partrun`, never by hand — binds the given socket, serves
/// exactly one coordinator connection, exits.  `GRAPHMP_PART_CRASH_ITER`
/// (with `GRAPHMP_PART_CRASH_WORKER`, default 0, matched against
/// `--worker-id`) injects a mid-iteration crash for the conformance tests.
#[cfg(unix)]
fn cmd_partworker(args: &Args) -> Result<()> {
    use graphmp::cluster::Worker;

    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let sock = PathBuf::from(args.req("socket")?);
    let worker_id = args.get_or("worker-id", "0").to_string();
    let mut worker = Worker::open(data, engine_config(args)?)?;
    if let Ok(spec) = std::env::var("GRAPHMP_PART_CRASH_ITER") {
        let target =
            std::env::var("GRAPHMP_PART_CRASH_WORKER").unwrap_or_else(|_| "0".to_string());
        if target == worker_id {
            worker.crash_iter = Some(spec.parse().context("GRAPHMP_PART_CRASH_ITER")?);
        }
    }
    let _ = std::fs::remove_file(&sock);
    let listener = std::os::unix::net::UnixListener::bind(&sock)
        .with_context(|| format!("binding worker socket {}", sock.display()))?;
    let (stream, _) = listener.accept().context("accepting the coordinator")?;
    let served = worker.serve_connection(stream);
    let _ = std::fs::remove_file(&sock);
    served
}

#[cfg(not(unix))]
fn cmd_partworker(_args: &Args) -> Result<()> {
    bail!("partworker is only available on unix")
}

/// The `--incremental` decision tree lives in
/// [`graphmp::engine::standing::incremental_run`]: monotone apps warm-start
/// (delete-bearing histories reset the affected closure first), single-pass
/// Sum apps refold only the mutated rows, everything else — and any
/// unreplayable history, or a fixpoint saved *ahead* of the run epoch —
/// recomputes cold with an explanation on stderr.
fn run_incremental(
    engine: &VswEngine,
    app: &apps::AnyProgram,
    data: &DatasetDir,
) -> Result<graphmp::engine::AnyRunResult> {
    use graphmp::engine::standing;

    let path = data.values_path(app.name());
    anyhow::ensure!(
        path.exists(),
        "no saved values for {} ({} missing) — run once with --save-values first",
        app.name(),
        path.display()
    );
    let adv = standing::incremental_run(data, engine, app)?;
    eprintln!("incremental: {} path to epoch {}", adv.mode.as_str(), engine.epoch());
    Ok(adv.result)
}

/// `graphmp watch`: one-shot register-or-advance of a standing query.
/// Emits changed lines (`<vertex> <bits>`) on stdout (or `--dump-changed`),
/// a summary on stderr; the persistent state lives next to the dataset.
fn cmd_watch(args: &Args) -> Result<()> {
    use graphmp::engine::standing;
    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let app = apps::by_name(args.req("app")?)?;
    if let Some(mbps) = args.get("throttle-mbps") {
        io::set_throttle(mbps.parse::<u64>().context("--throttle-mbps")? << 20);
    }
    let cfg = engine_config(args)?;
    anyhow::ensure!(
        cfg.epoch.is_none(),
        "watch refuses --epoch: a standing query always follows the latest epoch"
    );
    let window = match args.get("window") {
        Some(v) => Some(v.parse::<u32>().context("--window")?),
        None => None,
    };
    let engine = VswEngine::open(data.clone(), cfg)?;
    let out = standing::watch_advance(&data, &engine, &app, window)?;
    if let Some(path) = args.get("dump-changed") {
        let mut text = String::with_capacity(out.lines.len() * 16);
        for line in &out.lines {
            text.push_str(line);
            text.push('\n');
        }
        std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
        eprintln!("dumped {} changed lines -> {path}", out.lines.len());
    } else {
        for line in &out.lines {
            println!("{line}");
        }
    }
    eprintln!(
        "watch {}: epoch={} mode={} changed={}{}{}",
        app.name(),
        out.epoch,
        out.mode.as_str(),
        out.lines.len(),
        if out.registered { " [registered]" } else { "" },
        if out.expired > 0 { format!(" expired={}", out.expired) } else { String::new() },
    );
    io::set_throttle(0);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use graphmp::server::{Request, SchedulerConfig, Server};
    let ecfg = engine_config(args)?;
    let sched = SchedulerConfig {
        max_light: args.get_usize("max-light", SchedulerConfig::default().max_light)?,
        max_heavy: args.get_usize("max-heavy", SchedulerConfig::default().max_heavy)?,
        max_queue: args.get_usize("max-queue", SchedulerConfig::default().max_queue)?,
    };
    let ttl_secs = args.get_usize(
        "session-ttl-secs",
        Server::DEFAULT_SESSION_TTL.as_secs() as usize,
    )?;
    let ttl = (ttl_secs > 0).then(|| std::time::Duration::from_secs(ttl_secs as u64));
    let engine_ttl_secs = args.get_usize("engine-ttl-secs", 0)?;
    let engine_ttl =
        (engine_ttl_secs > 0).then(|| std::time::Duration::from_secs(engine_ttl_secs as u64));
    install_trace(args)?;
    let srv = Arc::new(
        Server::new(ecfg, sched)?.with_session_ttl(ttl).with_engine_ttl(engine_ttl),
    );
    // timer-tick eviction: abandoned sessions (and idle engines) are
    // reaped even on a daemon that never receives another request
    if let Some(t) = [ttl, engine_ttl].into_iter().flatten().min() {
        let _ = srv.spawn_sweeper(t.min(std::time::Duration::from_secs(1)));
    }
    // pre-load the named dataset so the first client doesn't pay the load
    if let Some(data) = args.get("data") {
        let resp = srv.handle(&Request::new("epoch").arg("data", data).render());
        if let Some(msg) = &resp.error {
            bail!("preloading {data}: {msg}");
        }
        eprintln!("preloaded {data} at epoch {}", resp.get("epoch").unwrap_or("?"));
    }
    let listener = std::net::TcpListener::bind(args.get_or("listen", "127.0.0.1:0"))
        .context("binding --listen")?;
    // the ready line clients and CI parse; flushed before blocking
    println!("listening {}", listener.local_addr()?);
    use std::io::Write as _;
    std::io::stdout().flush()?;
    if let Some(maddr) = args.get("metrics-listen") {
        let ml = std::net::TcpListener::bind(maddr).context("binding --metrics-listen")?;
        println!("metrics-listening {}", ml.local_addr()?);
        std::io::stdout().flush()?;
        let _ = srv.serve_metrics_http(ml);
    }
    #[cfg(unix)]
    if let Some(sock) = args.get("socket") {
        let path = PathBuf::from(sock);
        let _ = std::fs::remove_file(&path);
        let ul = std::os::unix::net::UnixListener::bind(&path)
            .with_context(|| format!("binding --socket {sock}"))?;
        println!("listening-unix {}", path.display());
        std::io::stdout().flush()?;
        let srv2 = srv.clone();
        std::thread::spawn(move || {
            let _ = srv2.serve_unix(ul, &path);
        });
    }
    #[cfg(not(unix))]
    anyhow::ensure!(args.get("socket").is_none(), "--socket is only available on unix");
    srv.serve_tcp(listener)?;
    finish_trace();
    eprintln!("serve: shut down");
    Ok(())
}

fn client_roundtrip<S: std::io::Read + std::io::Write>(
    mut stream: S,
    line: &str,
) -> Result<graphmp::server::Response> {
    use std::io::Write as _;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    graphmp::server::Response::read_from(&mut std::io::BufReader::new(stream))
}

fn cmd_client(args: &Args) -> Result<()> {
    let request_line = args.positional()[1..].join(" ");
    anyhow::ensure!(
        !request_line.trim().is_empty(),
        "client needs a request, e.g. `graphmp client --connect 127.0.0.1:4000 ping`"
    );
    let resp = match args.get("socket") {
        Some(sock) => {
            #[cfg(unix)]
            let r = client_roundtrip(
                std::os::unix::net::UnixStream::connect(sock)
                    .with_context(|| format!("connecting to socket {sock}"))?,
                &request_line,
            )?;
            #[cfg(not(unix))]
            let r = {
                let _ = sock;
                bail!("--socket is only available on unix")
            };
            r
        }
        None => {
            let addr = args.req("connect")?;
            client_roundtrip(
                std::net::TcpStream::connect(addr)
                    .with_context(|| format!("connecting to {addr}"))?,
                &request_line,
            )?
        }
    };
    if let Some(msg) = &resp.error {
        bail!("server: {msg}");
    }
    let header: Vec<String> = resp
        .kv
        .iter()
        .filter(|(k, _)| k != "lines")
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    println!("ok{}{}", if header.is_empty() { "" } else { " " }, header.join(" "));
    if let Some(out) = args.get("dump-values") {
        let mut s = String::with_capacity(resp.payload.len() * 9);
        for l in &resp.payload {
            s.push_str(l);
            s.push('\n');
        }
        std::fs::write(out, s).with_context(|| format!("writing {out}"))?;
        eprintln!("dumped {} values -> {out}", resp.payload.len());
    } else {
        for l in &resp.payload {
            println!("{l}");
        }
    }
    Ok(())
}

fn cmd_ingest(args: &Args) -> Result<()> {
    use graphmp::graph::mutation;
    use graphmp::storage::delta;
    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let batch_path = PathBuf::from(args.req("batch")?);
    let batch = delta::load_log_auto(&batch_path)
        .with_context(|| format!("reading mutation batch {}", batch_path.display()))?;
    let fpr = args.get_f64("bloom-fpr", 0.01)?;
    let t0 = std::time::Instant::now();
    let report = mutation::ingest(&data, &batch, fpr)?;
    let wall = t0.elapsed();
    let rate = (report.inserts + report.deletes) as f64 / wall.as_secs_f64().max(1e-9);
    println!(
        "epoch={} inserts={} deletes={} removed={} touched-shards={} |E|={} in {} ({:.0} mut/s)",
        report.epoch,
        report.inserts,
        report.deletes,
        report.edges_removed,
        report.touched_shards.len(),
        report.num_edges,
        humansize::duration(wall),
        rate
    );
    Ok(())
}

fn cmd_compact(args: &Args) -> Result<()> {
    use graphmp::graph::mutation;
    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let min_ratio = if args.has("all") { 0.0 } else { args.get_f64("min-ratio", 0.2)? };
    let t0 = std::time::Instant::now();
    let report = mutation::compact(&data, min_ratio)?;
    match report.epoch {
        Some(e) => println!(
            "epoch={} compacted-shards={} below-threshold={} in {}",
            e,
            report.compacted_shards.len(),
            report.skipped_shards,
            humansize::duration(t0.elapsed())
        ),
        None => println!(
            "nothing to compact ({} delta shard(s) below ratio {min_ratio})",
            report.skipped_shards
        ),
    }
    Ok(())
}

fn cmd_mutate_gen(args: &Args) -> Result<()> {
    use graphmp::graph::mutation;
    use graphmp::storage::delta;
    let data = DatasetDir::new(args.req("data")?);
    anyhow::ensure!(data.exists(), "{} is not a preprocessed dataset", data.root.display());
    let out = PathBuf::from(args.req("out")?);
    let count = args.get_usize("count", 0)?;
    anyhow::ensure!(count > 0, "--count must be positive");
    let seed = args.get_usize("seed", 1)? as u64;
    let delete_fraction = args.get_f64("delete-fraction", 0.2)?;
    let property = graphmp::storage::property::Property::load(&data.property_path())?;
    let (existing, _) = mutation::current_edges(&data)?;
    let batch = mutation::synth_batch(
        property.info.num_vertices as usize,
        &existing,
        count,
        delete_fraction,
        args.has("weighted"),
        seed,
    );
    delta::save_log(&batch, &out)?;
    let ins = batch.iter().filter(|m| m.is_insert()).count();
    println!(
        "wrote {} mutations ({} inserts, {} deletes) -> {}",
        batch.len(),
        ins,
        batch.len() - ins,
        out.display()
    );
    Ok(())
}

/// Lane-independent summary of a baseline run, for CLI printing.
struct BaselineSummary {
    iters: usize,
    total: std::time::Duration,
    read: u64,
    written: u64,
    mem: u64,
}

impl BaselineSummary {
    fn of<V>(run: &graphmp::baselines::BaselineRun<V>) -> Self {
        Self {
            iters: run.iter_walls.len(),
            total: run.total_wall,
            read: run.io.bytes_read,
            written: run.io.bytes_written,
            mem: run.memory_bytes,
        }
    }
}

fn cmd_baseline(args: &Args) -> Result<()> {
    use graphmp::apps::AnyProgram;
    let system = args.req("system")?;
    let input = PathBuf::from(args.req("data")?);
    let (edges, weights) = edgelist::read_auto_weighted(&input)?;
    let max_id = edges.iter().map(|&(s, d)| s.max(d)).max().unwrap_or(0) as usize;
    let vertices = args.get_usize("vertices", max_id + 1)?;
    let app = apps::by_name(args.req("app")?)?;
    let iters = args.get_usize("iters", 10)?;
    let work = std::env::temp_dir().join(format!("graphmp_baseline_{system}"));
    // dispatch the program's lane through the typed baseline path
    let summary = match &app {
        AnyProgram::F32(p) => BaselineSummary::of(&baselines::run_typed_by_name(
            system, work, &edges, &weights, vertices, p.as_ref(), iters,
        )?),
        AnyProgram::F64(p) => BaselineSummary::of(&baselines::run_typed_by_name(
            system, work, &edges, &weights, vertices, p.as_ref(), iters,
        )?),
        AnyProgram::U32(p) => BaselineSummary::of(&baselines::run_typed_by_name(
            system, work, &edges, &weights, vertices, p.as_ref(), iters,
        )?),
        AnyProgram::U64(p) => BaselineSummary::of(&baselines::run_typed_by_name(
            system, work, &edges, &weights, vertices, p.as_ref(), iters,
        )?),
    };
    println!(
        "system={} app={} lane={} iters={} total={} read={} written={} mem={}",
        baselines::display_name(system)?,
        app.name(),
        app.lane().name(),
        summary.iters,
        humansize::duration(summary.total),
        humansize::bytes(summary.read),
        humansize::bytes(summary.written),
        humansize::bytes(summary.mem),
    );
    Ok(())
}

/// The CI perf gate: compare a fresh `BENCH_pr.json` against the committed
/// `BENCH_baseline.json` and fail (exit 1 via error) on regression.
fn cmd_bench_compare(args: &Args) -> Result<()> {
    use graphmp::coordinator::benchjson;
    let baseline = PathBuf::from(args.req("baseline")?);
    let current = PathBuf::from(args.req("current")?);
    let tolerance = args.get_f64("tolerance", 0.25)?;
    let min_abs = args.get_f64("min-abs-secs", 0.25)?;
    let base = benchjson::load(&baseline)
        .with_context(|| format!("loading baseline {}", baseline.display()))?;
    let cur = benchjson::load(&current)
        .with_context(|| format!("loading current {}", current.display()))?;
    let report = benchjson::compare(&base, &cur, tolerance, min_abs);
    if let Some(md) = args.get("markdown") {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(md)
            .with_context(|| format!("opening --markdown {md}"))?;
        f.write_all(report.to_markdown().as_bytes())?;
    }
    for line in &report.lines {
        println!("{line}");
    }
    for warn in &report.stale_baseline {
        println!("WARNING stale baseline — {warn}");
    }
    if report.regressions.is_empty() {
        println!(
            "bench-compare: {} bench(es) within {:.0}% of baseline",
            report.compared,
            tolerance * 100.0
        );
        Ok(())
    } else {
        bail!(
            "bench-compare: {} regression(s) past the {:.0}% gate:\n  {}",
            report.regressions.len(),
            tolerance * 100.0,
            report.regressions.join("\n  ")
        )
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let data = DatasetDir::new(args.req("data")?);
    let p = graphmp::storage::property::Property::load(&data.property_path())?;
    println!("name:        {}", p.name);
    println!("vertices:    {}", p.info.num_vertices);
    println!("edges:       {}", p.info.num_edges);
    println!("avg degree:  {:.1}", p.info.avg_degree());
    println!("max in-deg:  {}", p.info.max_in_degree);
    println!("max out-deg: {}", p.info.max_out_degree);
    println!("shards:      {}", p.num_shards());
    if data.epochs_path().exists() {
        let m = graphmp::runtime::EpochManifest::load(&data.epochs_path())?;
        let cur = m.latest();
        let deltas = cur.shards.iter().filter(|s| s.delta.is_some()).count();
        println!("epoch:       {} ({} epochs, kind {})", m.current, m.epochs.len(), cur.kind);
        println!("live edges:  {}", cur.num_edges);
        println!("delta shards:{deltas}");
    }
    println!("simd:        {}", graphmp::engine::simd::level());
    println!("uring:       {}", graphmp::storage::uring::resolve_mode().name());
    Ok(())
}

fn cmd_trace_dump(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(str::to_string)
        .or_else(|| args.positional().get(1).cloned())
        .context("trace-dump needs a file, e.g. `graphmp trace-dump run.gmtf`")?;
    print!("{}", graphmp::obs::trace::dump(std::path::Path::new(&path))?);
    Ok(())
}

/// `graphmp top <addr>`: poll the daemon's `metrics` verb and render a
/// compact refresh — one daemon summary line plus one line per dataset.
fn cmd_top(args: &Args) -> Result<()> {
    use graphmp::obs::metrics as m;
    let addr = args
        .get("connect")
        .map(str::to_string)
        .or_else(|| args.positional().get(1).cloned())
        .context("top needs an address, e.g. `graphmp top 127.0.0.1:4000`")?;
    let interval =
        std::time::Duration::from_millis(args.get_usize("interval-ms", 1000)? as u64);
    let max_ticks = args.get_usize("iters", 0)?; // 0 = refresh forever
    let mut tick = 0usize;
    loop {
        tick += 1;
        let resp = client_roundtrip(
            std::net::TcpStream::connect(&addr)
                .with_context(|| format!("connecting to {addr}"))?,
            "metrics",
        )?;
        if let Some(msg) = &resp.error {
            bail!("server: {msg}");
        }
        let samples: Vec<(String, Vec<(String, String)>, f64)> =
            resp.payload.iter().filter_map(|l| m::parse_line(l)).collect();
        let label = |ls: &[(String, String)], key: &str| -> Option<String> {
            ls.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone())
        };
        // sum over every series of a family (collapses labels)
        let total = |name: &str| -> f64 {
            samples.iter().filter(|(n, _, _)| n == name).map(|(_, _, v)| v).sum()
        };
        // one series of a family, selected by a label value
        let at = |name: &str, key: &str, val: &str| -> f64 {
            samples
                .iter()
                .find(|(n, ls, _)| n == name && label(ls, key).as_deref() == Some(val))
                .map(|(_, _, v)| *v)
                .unwrap_or(0.0)
        };
        println!(
            "[{tick}] {addr}  sessions={} engines={} evicted={} jobs l/h/q={}/{}/{} \
             requests={} busy={} read={}",
            total("graphmp_sessions_open") as u64,
            total("graphmp_engines_resident") as u64,
            total("graphmp_engines_evicted_total") as u64,
            at("graphmp_jobs_inflight", "class", "light") as u64,
            at("graphmp_jobs_inflight", "class", "heavy") as u64,
            total("graphmp_jobs_queued") as u64,
            total("graphmp_requests_total") as u64,
            total("graphmp_admission_busy_total") as u64,
            humansize::bytes(total("graphmp_io_read_bytes_total") as u64),
        );
        let mut datasets: Vec<String> = samples
            .iter()
            .filter_map(|(_, ls, _)| label(ls, "dataset"))
            .collect();
        datasets.sort();
        datasets.dedup();
        for ds in &datasets {
            let get = |name: &str| at(name, "dataset", ds);
            let hits = get("graphmp_cache_hits_total");
            let misses = get("graphmp_cache_misses_total");
            let hit_pct =
                if hits + misses > 0.0 { 100.0 * hits / (hits + misses) } else { 0.0 };
            let io_wait = get("graphmp_engine_io_wait_seconds_total");
            let compute = get("graphmp_engine_compute_seconds_total");
            let busy = io_wait + compute;
            let io_pct = if busy > 0.0 { 100.0 * io_wait / busy } else { 0.0 };
            println!(
                "  {ds}: epoch={} iters={} window={} active={:.2}% hit={hit_pct:.0}% \
                 io-wait={io_pct:.0}% resident={} lent={}",
                get("graphmp_engine_epoch") as u64,
                get("graphmp_engine_iterations_total") as u64,
                get("graphmp_engine_window") as u64,
                get("graphmp_engine_active_ratio") * 100.0,
                humansize::bytes(get("graphmp_cache_resident_bytes") as u64),
                humansize::bytes(get("graphmp_engine_lent_bytes") as u64),
            );
        }
        if max_ticks > 0 && tick >= max_ticks {
            break;
        }
        std::thread::sleep(interval);
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<12} {:<28} {:>10} {:>12} {:>8}", "name", "stands in for", "|V|", "|E|", "avg-deg");
    for d in &DATASETS {
        println!(
            "{:<12} {:<28} {:>10} {:>12} {:>8.1}",
            d.name,
            d.stands_in_for,
            humansize::count(d.num_vertices() as u64),
            humansize::count(d.num_edges),
            d.avg_degree()
        );
    }
    Ok(())
}
