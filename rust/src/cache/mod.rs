//! Compressed edge cache (paper §II-D.2).
//!
//! Spare RAM caches shards so re-iterations skip disk.  The paper's four
//! modes map onto [`codec::Codec`]:
//!
//! | paper  | here          | notes                                        |
//! |--------|---------------|----------------------------------------------|
//! | mode-1 | `Codec::None` | uncompressed                                  |
//! | mode-2 | `Codec::SnapLite` | hand-rolled LZ77 byte codec (no snap crate) |
//! | mode-3 | `Codec::Zlib1`| flate2 level 1                                |
//! | mode-4 | `Codec::Zlib3`| flate2 level 3                                |
//! | extra  | `Codec::Zstd1`| zstd level 1 (extension, ablation-only)       |
//! | extra  | `Codec::DeltaVarint` | domain codec over CSR (extension)      |
//!
//! [`ShardCache`] enforces a byte budget with sharded locking and CLOCK
//! eviction; `get` decompresses on hit, `insert` compresses on store.

pub mod codec;
pub mod deltavarint;
pub mod snaplite;

mod store;

pub use codec::{CacheMode, Codec};
pub use store::{CacheStats, ShardCache, ShardView};
