//! SnapLite: a snappy-style LZ77 byte compressor built from scratch.
//!
//! The offline crate set has no snappy binding, and the paper's mode-2
//! needs a "cheap, modest-ratio" codec, so this implements the same design
//! point: greedy LZ77 with a 64 Ki hash table over 4-byte prefixes,
//! varint-framed literal/copy ops, no entropy stage.  Typical CSR shard
//! payloads compress ~1.6–2.5× at multi-GB/s-class speeds.
//!
//! Format (after an 8-byte LE uncompressed-length header):
//! ```text
//! tag byte: low bit 0 => literal run, len = tag>>1 (+ varint ext if 127)
//!           low bit 1 => copy, len = (tag>>1)+MIN_MATCH (+ varint ext)
//!                        followed by varint distance (>=1)
//! ```

use anyhow::{bail, ensure, Result};

use crate::util::varint;

const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 16;
const MAX_CHAIN_DIST: usize = 1 << 20; // 1 MiB window

#[inline]
fn hash4(b: &[u8]) -> usize {
    let x = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (x.wrapping_mul(0x9E3779B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `input`; output always parses back exactly.
pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    out.extend_from_slice(&(input.len() as u64).to_le_bytes());
    if input.is_empty() {
        return out;
    }

    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut pos = 0usize;
    let mut lit_start = 0usize;

    let flush_literals = |out: &mut Vec<u8>, lit: &[u8]| {
        let mut rem = lit;
        while !rem.is_empty() {
            let take = rem.len();
            // tag: low bit 0, len field 7 bits; 127 means "varint extension"
            if take < 127 {
                out.push((take as u8) << 1);
            } else {
                out.push(127 << 1);
                varint::write_u64(out, (take - 127) as u64);
            }
            out.extend_from_slice(&rem[..take]);
            rem = &rem[take..];
        }
    };

    while pos + MIN_MATCH <= input.len() {
        let h = hash4(&input[pos..]);
        let cand = table[h];
        table[h] = pos;
        let mut matched = 0usize;
        if cand != usize::MAX
            && pos - cand <= MAX_CHAIN_DIST
            && input[cand..cand + MIN_MATCH] == input[pos..pos + MIN_MATCH]
        {
            // extend
            let mut len = MIN_MATCH;
            let max = input.len() - pos;
            while len < max && input[cand + len] == input[pos + len] {
                len += 1;
            }
            matched = len;
        }
        if matched >= MIN_MATCH {
            flush_literals(&mut out, &input[lit_start..pos]);
            let dist = pos - table_pos_fix(cand);
            // tag: low bit 1, len-MIN_MATCH in 7 bits; 127 => varint ext
            let lcode = matched - MIN_MATCH;
            if lcode < 127 {
                out.push(((lcode as u8) << 1) | 1);
            } else {
                out.push((127 << 1) | 1);
                varint::write_u64(&mut out, (lcode - 127) as u64);
            }
            varint::write_u64(&mut out, dist as u64);
            // seed hash table sparsely inside the match (every 4th byte)
            let end = pos + matched;
            let mut p = pos + 1;
            while p + MIN_MATCH <= input.len() && p < end {
                table[hash4(&input[p..])] = p;
                p += 4;
            }
            pos = end;
            lit_start = pos;
        } else {
            pos += 1;
        }
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

#[inline]
fn table_pos_fix(cand: usize) -> usize {
    cand
}

/// Decompress a [`compress`] output.
pub fn decompress(input: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_into(input, &mut out)?;
    Ok(out)
}

/// [`decompress`] into a caller-owned buffer (cleared first), so a hot
/// loop can reuse one allocation across payloads — the compressed edge
/// cache decompresses every cached shard every iteration, and this is
/// what keeps that steady state allocation-free.
pub fn decompress_into(input: &[u8], out: &mut Vec<u8>) -> Result<()> {
    ensure!(input.len() >= 8, "snaplite: header truncated");
    let expect = u64::from_le_bytes(input[0..8].try_into().unwrap()) as usize;
    out.clear();
    out.reserve(expect);
    let mut pos = 8usize;
    while pos < input.len() {
        let tag = input[pos];
        pos += 1;
        let mut field = (tag >> 1) as usize;
        if field == 127 {
            let Some((ext, p)) = varint::read_u64(input, pos) else {
                bail!("snaplite: bad length extension");
            };
            field += ext as usize;
            pos = p;
        }
        if tag & 1 == 0 {
            // literal run
            ensure!(pos + field <= input.len(), "snaplite: literal overruns input");
            out.extend_from_slice(&input[pos..pos + field]);
            pos += field;
        } else {
            // copy
            let len = field + MIN_MATCH;
            let Some((dist, p)) = varint::read_u64(input, pos) else {
                bail!("snaplite: bad distance");
            };
            pos = p;
            let dist = dist as usize;
            ensure!(dist >= 1 && dist <= out.len(), "snaplite: distance {dist} out of range");
            // memcpy-sized spans instead of byte pushes (§Perf opt-3).
            // Overlapping copies (dist < len) materialize in passes whose
            // available window doubles as the output grows.
            let start = out.len() - dist;
            let mut copied = 0;
            while copied < len {
                let src = start + copied;
                let n = (out.len() - src).min(len - copied);
                out.extend_from_within(src..src + n);
                copied += n;
            }
        }
    }
    ensure!(out.len() == expect, "snaplite: length mismatch {} vs {}", out.len(), expect);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn roundtrip_edges() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abcd");
        roundtrip(b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa");
        roundtrip("hello hello hello hello world world".as_bytes());
    }

    #[test]
    fn roundtrip_long_runs_and_overlaps() {
        let mut v = Vec::new();
        for i in 0..10_000u32 {
            v.extend_from_slice(&(i % 7).to_le_bytes());
        }
        roundtrip(&v);
        // single repeated byte => dist 1 overlapping copies
        roundtrip(&vec![0x42u8; 100_000]);
    }

    #[test]
    fn compresses_csr_like_data() {
        // sorted u32 ids with small deltas — shard col array shape
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut ids: Vec<u32> = (0..50_000).map(|_| rng.gen_range(1 << 20) as u32).collect();
        ids.sort_unstable();
        let bytes: Vec<u8> = ids.iter().flat_map(|x| x.to_le_bytes()).collect();
        let c = compress(&bytes);
        assert!(c.len() < bytes.len(), "no compression: {} vs {}", c.len(), bytes.len());
        assert_eq!(decompress(&c).unwrap(), bytes);
    }

    #[test]
    fn incompressible_data_expands_bounded() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let c = compress(&data);
        // worst case: 8B header + ~1 tag per 126 literals
        assert!(c.len() < data.len() + data.len() / 64 + 64);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn decompress_into_reuses_buffer_across_payloads() {
        let small = compress(b"hello hello hello hello");
        let big = compress(&vec![7u8; 4096]);
        let mut buf = Vec::new();
        decompress_into(&big, &mut buf).unwrap();
        assert_eq!(buf, vec![7u8; 4096]);
        // big -> small: contents replaced, capacity retained for reuse
        decompress_into(&small, &mut buf).unwrap();
        assert_eq!(buf, b"hello hello hello hello");
        assert!(buf.capacity() >= 4096);
    }

    #[test]
    fn rejects_corrupt_streams() {
        let c = compress(b"some compressible compressible data data data");
        assert!(decompress(&c[..4]).is_err());
        let mut bad = c.clone();
        let last = bad.len() - 1;
        bad.truncate(last); // drop final byte => length mismatch or overrun
        assert!(decompress(&bad).is_err());
    }

    #[test]
    fn prop_arbitrary_bytes_roundtrip() {
        prop::check(0x5A17, 60, |g| {
            let n = g.usize_in(0, 4096);
            // mix of random and runs to hit both paths
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if g.bool(0.5) {
                    let b = g.u64() as u8;
                    let run = g.usize_in(1, 64).min(n - data.len());
                    data.extend(std::iter::repeat_n(b, run));
                } else {
                    data.push(g.u64() as u8);
                }
            }
            let c = compress(&data);
            assert_eq!(decompress(&c).unwrap(), data);
        });
    }
}
