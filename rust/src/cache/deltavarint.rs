//! Delta-varint: a domain-specific codec for CSR shard payloads.
//!
//! Exploits shard structure the byte codecs cannot see: `row_ptr` is
//! monotone (delta = per-row degree, tiny), and each row's `col` list is
//! sorted ascending after a normalization pass (GraphMP semantics do not
//! depend on in-neighbor order), so consecutive ids delta-encode into 1-2
//! byte varints.  On power-law shards this reaches 3-5×, beating zlib-3 at
//! snappy-class speed — the "compact data structure" the paper credits for
//! fitting EU-2015's 91.8 B edges into a 68 GB cache.
//!
//! Weighted shards interleave each edge's weight (its 4 raw little-endian
//! `f32` bytes — bit patterns have high-entropy low bits, so a varint
//! would *expand* them to 5 bytes) right after the source delta, so the
//! weight rides next to its target and the row normalization keeps
//! `(src, weight)` pairs together.  A flags varint after the interval
//! header says whether the weight lane is present.

use anyhow::{ensure, Result};

use crate::graph::csr::Csr;
use crate::graph::Weight;
use crate::util::varint;

/// Flags bit: the payload carries a per-edge weight lane.
const FLAG_WEIGHTED: u64 = 1;

/// Encode a CSR shard (sorts each row's `(src, weight)` pairs; in-neighbor
/// order is not semantic).
pub fn encode(csr: &Csr) -> Vec<u8> {
    let weighted = csr.is_weighted();
    let mut out = Vec::with_capacity(csr.col.len() + csr.row_ptr.len() + 16);
    varint::write_u64(&mut out, csr.lo as u64);
    varint::write_u64(&mut out, (csr.hi - csr.lo) as u64);
    varint::write_u64(&mut out, if weighted { FLAG_WEIGHTED } else { 0 });
    // row_ptr deltas = degrees
    for w in csr.row_ptr.windows(2) {
        varint::write_u64(&mut out, (w[1] - w[0]) as u64);
    }
    // per-row sorted source deltas, weight bits interleaved
    let n = csr.num_vertices();
    let mut row: Vec<(u32, u32)> = Vec::new();
    for i in 0..n {
        let s = csr.row_ptr[i] as usize;
        let e = csr.row_ptr[i + 1] as usize;
        row.clear();
        row.extend((s..e).map(|k| (csr.col[k], csr.weight(k).to_bits())));
        row.sort_unstable();
        let mut prev = 0u32;
        for (j, &(src, wbits)) in row.iter().enumerate() {
            if j == 0 {
                varint::write_u64(&mut out, src as u64);
            } else {
                varint::write_u64(&mut out, (src - prev) as u64);
            }
            if weighted {
                out.extend_from_slice(&wbits.to_le_bytes());
            }
            prev = src;
        }
    }
    out
}

/// Decode back to a CSR (rows come back sorted).
pub fn decode(buf: &[u8]) -> Result<Csr> {
    let mut pos = 0usize;
    let (lo, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: lo"))?;
    pos = p;
    let (width, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: width"))?;
    pos = p;
    let (flags, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: flags"))?;
    pos = p;
    ensure!(flags & !FLAG_WEIGHTED == 0, "dv: unknown flags {flags:#x}");
    let weighted = flags & FLAG_WEIGHTED != 0;
    let n = width as usize;
    let mut row_ptr = Vec::with_capacity(n + 1);
    row_ptr.push(0u32);
    let mut total = 0u64;
    for _ in 0..n {
        let (d, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: degree"))?;
        pos = p;
        total += d;
        ensure!(total <= u32::MAX as u64, "dv: too many edges");
        row_ptr.push(total as u32);
    }
    let mut col = Vec::with_capacity(total as usize);
    let mut wgt: Vec<Weight> =
        if weighted { Vec::with_capacity(total as usize) } else { Vec::new() };
    for i in 0..n {
        let deg = (row_ptr[i + 1] - row_ptr[i]) as usize;
        let mut prev = 0u64;
        for j in 0..deg {
            let (d, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: col"))?;
            pos = p;
            let v = if j == 0 { d } else { prev + d };
            ensure!(v <= u32::MAX as u64, "dv: col overflow");
            col.push(v as u32);
            prev = v;
            if weighted {
                ensure!(buf.len() >= pos + 4, "dv: weight truncated");
                let wbits = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
                pos += 4;
                wgt.push(f32::from_bits(wbits));
            }
        }
    }
    ensure!(pos == buf.len(), "dv: trailing bytes");
    let csr = Csr { lo: lo as u32, hi: (lo + width) as u32, row_ptr, col, wgt };
    csr.validate()?;
    Ok(csr)
}

// ---- compressed-domain walking ---------------------------------------------
//
// The cursor API lets the engine gather straight out of a delta-varint
// payload: no `row_ptr`/`col`/`wgt` vectors are ever materialized.  A
// [`plan`] pass validates the payload end-to-end (same rejections as
// [`decode`]) and records per-chunk byte offsets so a shard's rows can be
// decoded independently on several cores; each [`DvCursor`] then streams
// its chunk's rows in exactly the order [`decode`] would store them, so a
// fold over the cursor is bit-identical to a fold over the decoded CSR.

/// One independently decodable run of rows inside a payload.
#[derive(Debug, Clone, Copy)]
pub struct DvChunk {
    /// Covered rows `[start_row, end_row)`, shard-local.
    pub start_row: usize,
    pub end_row: usize,
    /// Byte offset of `start_row`'s degree varint.
    deg_pos: usize,
    /// Byte offset of `start_row`'s first source varint.
    col_pos: usize,
}

/// A validated chunked walk plan over one delta-varint payload.
#[derive(Debug, Clone)]
pub struct DvPlan {
    pub lo: u32,
    pub num_rows: usize,
    pub weighted: bool,
    pub num_edges: usize,
    pub chunks: Vec<DvChunk>,
}

/// Scan `buf` once — validating exactly what [`decode`] validates, but
/// materializing nothing — and split its rows into chunks of at most
/// `chunk_rows` (0 ⇒ a single chunk).  The scan is the codec's full
/// integrity check: truncation, unknown flags, column overflow and
/// trailing bytes are all rejected here, so cursor walks over a planned
/// payload only fail on logic bugs.
pub fn plan(buf: &[u8], chunk_rows: usize) -> Result<DvPlan> {
    let chunk_rows = if chunk_rows == 0 { usize::MAX } else { chunk_rows };
    let mut pos = 0usize;
    let (lo, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: lo"))?;
    pos = p;
    let (width, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: width"))?;
    pos = p;
    let (flags, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: flags"))?;
    pos = p;
    ensure!(flags & !FLAG_WEIGHTED == 0, "dv: unknown flags {flags:#x}");
    ensure!(
        lo.checked_add(width).is_some_and(|hi| hi <= u32::MAX as u64),
        "dv: interval overflow"
    );
    let weighted = flags & FLAG_WEIGHTED != 0;
    let n = width as usize;

    // pass 1: the degree section — total edge count and the column start
    let deg_start = pos;
    let mut total = 0u64;
    for _ in 0..n {
        let (d, p) = varint::read_u64(buf, pos).ok_or_else(|| anyhow::anyhow!("dv: degree"))?;
        pos = p;
        total = total.saturating_add(d);
        ensure!(total <= u32::MAX as u64, "dv: too many edges");
    }
    let col_start = pos;

    // pass 2: walk the column section row by row (degrees re-read from the
    // degree section with a second pointer), recording chunk boundaries
    let mut chunks = Vec::with_capacity(if n == 0 { 1 } else { n.div_ceil(chunk_rows) });
    let mut deg_pos = deg_start;
    let mut col_pos = col_start;
    for row in 0..n {
        if row % chunk_rows == 0 {
            chunks.push(DvChunk {
                start_row: row,
                end_row: row.saturating_add(chunk_rows).min(n),
                deg_pos,
                col_pos,
            });
        }
        let (d, p) =
            varint::read_u64(buf, deg_pos).ok_or_else(|| anyhow::anyhow!("dv: degree"))?;
        deg_pos = p;
        let mut prev = 0u64;
        for j in 0..d {
            let (delta, p) =
                varint::read_u64(buf, col_pos).ok_or_else(|| anyhow::anyhow!("dv: col"))?;
            col_pos = p;
            // saturating: an adversarial delta rejects via the range check
            let v = if j == 0 { delta } else { prev.saturating_add(delta) };
            ensure!(v <= u32::MAX as u64, "dv: col overflow");
            prev = v;
            if weighted {
                ensure!(buf.len() >= col_pos + 4, "dv: weight truncated");
                col_pos += 4;
            }
        }
    }
    ensure!(col_pos == buf.len(), "dv: trailing bytes");
    if chunks.is_empty() {
        chunks.push(DvChunk { start_row: 0, end_row: 0, deg_pos, col_pos });
    }
    Ok(DvPlan {
        lo: lo as u32,
        num_rows: n,
        weighted,
        num_edges: total as usize,
        chunks,
    })
}

impl DvPlan {
    /// A streaming cursor over one of this plan's chunks.  `buf` must be
    /// the same payload the plan was built from.
    pub fn cursor<'a>(&self, buf: &'a [u8], chunk: &DvChunk) -> DvCursor<'a> {
        DvCursor {
            buf,
            weighted: self.weighted,
            deg_pos: chunk.deg_pos,
            col_pos: chunk.col_pos,
            row: chunk.start_row,
            end_row: chunk.end_row,
        }
    }
}

/// Streams one chunk's rows straight out of the varint payload, in the
/// exact per-row sorted order [`decode`] materializes.
pub struct DvCursor<'a> {
    buf: &'a [u8],
    weighted: bool,
    deg_pos: usize,
    col_pos: usize,
    row: usize,
    end_row: usize,
}

impl DvCursor<'_> {
    pub fn rows_left(&self) -> usize {
        self.end_row - self.row
    }

    /// Decode the next row, calling `f(src, weight)` once per in-edge
    /// (weight 1.0 on unweighted payloads).
    #[inline]
    pub fn next_row<F: FnMut(u32, f32)>(&mut self, mut f: F) -> Result<()> {
        ensure!(self.row < self.end_row, "dv: cursor walked past its chunk");
        let (d, p) = varint::read_u64(self.buf, self.deg_pos)
            .ok_or_else(|| anyhow::anyhow!("dv: degree"))?;
        self.deg_pos = p;
        let mut prev = 0u64;
        for j in 0..d {
            let (delta, p) = varint::read_u64(self.buf, self.col_pos)
                .ok_or_else(|| anyhow::anyhow!("dv: col"))?;
            self.col_pos = p;
            // saturating: an adversarial delta rejects via the range check
            let v = if j == 0 { delta } else { prev.saturating_add(delta) };
            ensure!(v <= u32::MAX as u64, "dv: col overflow");
            prev = v;
            let w = if self.weighted {
                ensure!(self.buf.len() >= self.col_pos + 4, "dv: weight truncated");
                let bits =
                    u32::from_le_bytes(self.buf[self.col_pos..self.col_pos + 4].try_into().unwrap());
                self.col_pos += 4;
                f32::from_bits(bits)
            } else {
                1.0
            };
            f(v as u32, w);
        }
        self.row += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::prop;

    fn normalize(mut csr: Csr) -> Csr {
        // sort each row's (src, weight-bits) pairs for comparison
        let n = csr.num_vertices();
        for i in 0..n {
            let s = csr.row_ptr[i] as usize;
            let e = csr.row_ptr[i + 1] as usize;
            if csr.is_weighted() {
                let mut pairs: Vec<(u32, u32)> =
                    (s..e).map(|k| (csr.col[k], csr.wgt[k].to_bits())).collect();
                pairs.sort_unstable();
                for (off, (src, wbits)) in pairs.into_iter().enumerate() {
                    csr.col[s + off] = src;
                    csr.wgt[s + off] = f32::from_bits(wbits);
                }
            } else {
                csr.col[s..e].sort_unstable();
            }
        }
        csr
    }

    #[test]
    fn roundtrip_small() {
        let csr = Csr::from_edges(5, 8, &[(9, 5), (2, 5), (2, 7), (0, 7), (1, 6)]);
        let back = decode(&encode(&csr)).unwrap();
        assert_eq!(back, normalize(csr));
    }

    #[test]
    fn roundtrip_weighted_keeps_pairs_together() {
        let edges = [(9u32, 5u32), (2, 5), (2, 7), (0, 7), (1, 6)];
        let weights = [1.5f32, 0.25, 2.0, 0.5, 1.0];
        let csr = Csr::from_edges_weighted(5, 8, &edges, &weights);
        let back = decode(&encode(&csr)).unwrap();
        assert!(back.is_weighted());
        assert_eq!(back, normalize(csr.clone()));
        // the (src, dst, weight) multiset is preserved exactly
        let mut a = back.to_wedges();
        let mut b = csr.to_wedges();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn roundtrip_empty_rows() {
        let csr = Csr::from_edges(0, 5, &[(3, 2)]);
        let back = decode(&encode(&csr)).unwrap();
        assert_eq!(back, normalize(csr));
    }

    #[test]
    fn beats_raw_on_powerlaw_shard() {
        let edges = generator::rmat(12, 40_000, generator::RmatParams::default(), 9);
        let in_range: Vec<_> = edges.iter().copied().filter(|&(_, d)| d < 1024).collect();
        let csr = Csr::from_edges(0, 1024, &in_range);
        let raw = crate::storage::shardfile::to_bytes(&csr).len();
        let dv = encode(&csr).len();
        assert!(
            (dv as f64) < 0.5 * raw as f64,
            "delta-varint ratio too weak: {dv} vs {raw}"
        );
    }

    #[test]
    fn rejects_truncation() {
        let csr = Csr::from_edges(0, 4, &[(1, 0), (2, 1), (3, 2)]);
        let buf = encode(&csr);
        for cut in 0..buf.len() {
            assert!(decode(&buf[..cut]).is_err(), "accepted truncation at {cut}");
        }
    }

    #[test]
    fn rejects_weighted_truncation() {
        let csr = Csr::from_edges_weighted(
            0,
            4,
            &[(1, 0), (2, 1), (3, 2)],
            &[0.5, 1.5, 2.5],
        );
        let buf = encode(&csr);
        for cut in 0..buf.len() {
            assert!(decode(&buf[..cut]).is_err(), "accepted truncation at {cut}");
        }
    }

    /// Walk every chunk of a plan, reconstructing (row, src, weight-bits)
    /// triples in visit order.
    fn walk(buf: &[u8], chunk_rows: usize) -> (DvPlan, Vec<(usize, u32, u32)>) {
        let plan = plan(buf, chunk_rows).unwrap();
        let mut out = Vec::new();
        for chunk in &plan.chunks {
            let mut cur = plan.cursor(buf, chunk);
            for row in chunk.start_row..chunk.end_row {
                cur.next_row(|s, w| out.push((row, s, w.to_bits()))).unwrap();
            }
            assert_eq!(cur.rows_left(), 0);
        }
        (plan, out)
    }

    /// The decoded CSR flattened in the same (row, src, weight) order the
    /// cursor streams.
    fn decoded_triples(csr: &Csr) -> Vec<(usize, u32, u32)> {
        let mut out = Vec::new();
        for i in 0..csr.num_vertices() {
            for k in csr.row_ptr[i] as usize..csr.row_ptr[i + 1] as usize {
                out.push((i, csr.col[k], csr.weight(k).to_bits()));
            }
        }
        out
    }

    #[test]
    fn cursor_streams_exactly_what_decode_materializes() {
        for weighted in [false, true] {
            let edges = [(9u32, 5u32), (2, 5), (2, 7), (0, 7), (1, 6), (2, 5)];
            let weights: Vec<f32> =
                if weighted { vec![1.5, 0.25, 2.0, 0.5, 1.0, 0.125] } else { Vec::new() };
            let csr = Csr::from_edges_weighted(5, 9, &edges, &weights);
            let buf = encode(&csr);
            let decoded = decode(&buf).unwrap();
            for chunk_rows in [0usize, 1, 2, 3, 100] {
                let (p, triples) = walk(&buf, chunk_rows);
                assert_eq!(p.lo, 5);
                assert_eq!(p.num_rows, 4);
                assert_eq!(p.weighted, weighted);
                assert_eq!(p.num_edges, 6);
                assert_eq!(triples, decoded_triples(&decoded), "chunk_rows={chunk_rows}");
            }
        }
    }

    #[test]
    fn plan_rejects_what_decode_rejects() {
        let csr = Csr::from_edges_weighted(
            0,
            4,
            &[(1, 0), (2, 1), (3, 2)],
            &[0.5, 1.5, 2.5],
        );
        let buf = encode(&csr);
        for cut in 0..buf.len() {
            assert!(plan(&buf[..cut], 2).is_err(), "plan accepted truncation at {cut}");
        }
        // trailing garbage is rejected too
        let mut long = buf.clone();
        long.push(0);
        assert!(plan(&long, 2).is_err());
        assert!(plan(&buf, 2).is_ok());
    }

    #[test]
    fn prop_roundtrip_random_shards() {
        prop::check(0xDE17A, 40, |g| {
            let lo = g.usize_in(0, 50) as u32;
            let width = g.usize_in(1, 80) as u32;
            let m = g.usize_in(0, 400);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    (
                        g.usize_in(0, 100_000) as u32,
                        lo + g.usize_in(0, width as usize) as u32,
                    )
                })
                .collect();
            let weights: Vec<f32> = if g.bool(0.5) {
                (0..m).map(|_| (g.usize_in(1, 32) as f32) * 0.125).collect()
            } else {
                Vec::new()
            };
            let csr = Csr::from_edges_weighted(lo, lo + width, &edges, &weights);
            let back = decode(&encode(&csr)).unwrap();
            assert_eq!(back, normalize(csr));
        });
    }
}
