//! The shard cache store: byte-budgeted, sharded-lock, CLOCK eviction.
//!
//! §II-D.2 semantics: on shard load, first probe the cache; hit ⇒ no disk
//! access (decompress if the mode compresses); miss ⇒ read disk, then insert
//! if the budget allows.  The paper "maximizes the number of cached shards
//! with limited memory" — CLOCK eviction approximates LRU without a global
//! lock on every hit.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cache::codec::Codec;
use crate::graph::csr::Csr;
use crate::storage::shardfile;

/// Cache hit/miss/eviction counters.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub insertions: AtomicU64,
    pub evictions: AtomicU64,
    pub rejected: AtomicU64,
    /// Slots dropped because their shard's file epoch moved on (a
    /// compaction rewrote the base shard under a live cache).
    pub invalidated: AtomicU64,
    /// Total decompression time, ns (the paper's mode-selection cost).
    pub decompress_ns: AtomicU64,
    pub compress_ns: AtomicU64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// What a slot holds.  Mode-1 ("uncompressed") stores the *decoded* CSR
/// behind an `Arc` — the paper's uncompressed cache keeps the in-memory
/// shard representation, and returning a clone of the Arc makes a cache hit
/// allocation-free (§Perf opt-2: -31% steady-iteration time).  Compressing
/// codecs store the compressed bytes — also behind an `Arc`, so a hit can
/// share the slot's payload with the compressed-domain gather path (or
/// decompress it) without copying a byte or holding the slot lock.
enum CacheVal {
    Bytes(Arc<Vec<u8>>),
    Decoded(Arc<Csr>),
}

impl CacheVal {
    fn size(&self) -> usize {
        match self {
            CacheVal::Bytes(b) => b.len(),
            CacheVal::Decoded(c) => shardfile::estimated_bytes(c),
        }
    }
}

/// What [`ShardCache::fetch_view`] hands the engine: the cheapest faithful
/// representation of the shard it could produce.  `Decoded` is the mode-1
/// hit (and mode-1 admission) — a clone of the cached `Arc<Csr>`.
/// `Compressed` is a compressing-codec hit: the slot's payload shared by
/// `Arc` (no `payload.clone()`, no decode) for the caller to walk in the
/// compressed domain or decompress into its own scratch.  `Raw` is a disk
/// read that was not (or could not be) admitted decoded: the serialized
/// shard bytes, ready for an in-place
/// [`crate::storage::shardfile::parse_layout`] walk.
pub enum ShardView {
    Decoded(Arc<Csr>),
    Compressed { codec: Codec, bytes: Arc<Vec<u8>> },
    Raw(Arc<Vec<u8>>),
}

struct Slot {
    /// Cached shard; None = empty slot.
    data: Option<CacheVal>,
    /// CLOCK reference bit.
    referenced: AtomicBool,
    /// File epoch the payload was admitted under — the caller's
    /// `shard_epoch` at admission time.  A probe whose expected epoch
    /// disagrees drops the slot instead of serving stale bytes.
    ///
    /// Ordering audit: `epoch` is read and written **only under the slot
    /// mutex**, in the same critical section that reads/writes `data`, so
    /// the payload↔epoch pairing is indivisible — no atomics ordering is
    /// involved in the correctness gate.  The `stats.invalidated` counter
    /// (and every other `CacheStats` field) is `Relaxed` because it is
    /// purely diagnostic: nothing branches on it.
    epoch: u64,
    /// Per-shard probe history (under the slot lock) — the governor's
    /// "how disk-bound has this shard been" signal.
    hits: u64,
    misses: u64,
}

/// Byte-budgeted shard cache indexed by shard id.
///
/// Admission policy: **no-evict** by default.  The VSW engine sweeps shards
/// cyclically (0..P every iteration); under that pattern any LRU-like
/// replacement degenerates to a 0% hit ratio (each shard is evicted just
/// before its next use), while pinning whichever prefix fits yields the
/// optimal `budget/total` hit ratio (§Perf opt-4).  CLOCK eviction remains
/// available via [`ShardCache::with_eviction`] for non-cyclic access
/// patterns.
///
/// ## Epoch keying
///
/// Every probe/insert carries the **caller's** expected file epoch — the
/// `shard_epoch` recorded in the epoch snapshot the caller is pinned to
/// (compaction rewrites a base shard file and bumps it; ingest leaves base
/// bytes alone, so residents stay valid).  A slot serves a payload only to
/// callers whose epoch matches the one it was admitted under, so readers
/// pinned to different epochs can share one cache without ever being
/// handed each other's bytes.  There is no cache-global epoch table to
/// re-key on refresh: the earlier design stamped inserts from a shared
/// `expected_epochs` array, which let a reader that had opened an *old*
/// shard file admit those stale bytes under the *new* epoch if a
/// compaction slid in between the read and the insert — per-call keying
/// makes that pairing indivisible (see [`Slot::epoch`]'s ordering audit).
pub struct ShardCache {
    slots: Vec<Mutex<Slot>>,
    codec: Codec,
    budget: usize,
    used: AtomicUsize,
    clock_hand: AtomicUsize,
    evict: bool,
    /// Per-shard eviction priorities (higher = keep longer), installed by
    /// the adaptive governor each iteration; empty = CLOCK order.
    priorities: Mutex<Vec<u64>>,
    /// Freelist of payload-decode scratch buffers: a compressed-codec
    /// `get` decompresses into one of these (reusing its capacity slot)
    /// instead of allocating a shard-sized buffer per hit.  Bounded so a
    /// burst of concurrent decodes can't pin shard-sized allocations
    /// forever.
    scratch: Mutex<Vec<Vec<u8>>>,
    pub stats: CacheStats,
}

/// Max buffers the decode-scratch freelist retains.
const SCRATCH_MAX: usize = 8;

impl ShardCache {
    /// Cache for `num_shards` shards with a total compressed-byte `budget`.
    /// `budget = usize::MAX` means "unbounded" (the paper's cache-everything
    /// case when spare RAM exceeds the compressed graph).
    pub fn new(num_shards: usize, codec: Codec, budget: usize) -> Self {
        Self {
            slots: (0..num_shards)
                .map(|_| {
                    Mutex::new(Slot {
                        data: None,
                        referenced: AtomicBool::new(false),
                        epoch: 0,
                        hits: 0,
                        misses: 0,
                    })
                })
                .collect(),
            codec,
            budget,
            used: AtomicUsize::new(0),
            clock_hand: AtomicUsize::new(0),
            evict: false,
            priorities: Mutex::new(Vec::new()),
            scratch: Mutex::new(Vec::new()),
            stats: CacheStats::default(),
        }
    }

    fn take_scratch(&self) -> Vec<u8> {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    fn put_scratch(&self, buf: Vec<u8>) {
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < SCRATCH_MAX {
            pool.push(buf);
        }
    }

    /// Switch to CLOCK replacement (second-chance LRU approximation).
    pub fn with_eviction(mut self) -> Self {
        self.evict = true;
        self
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    pub fn used_bytes(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn num_cached(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.lock().unwrap().data.is_some())
            .count()
    }

    /// Probe the slot under its lock; on hit the payload comes back as a
    /// cheap `Arc` clone and the hit/miss accounting is updated.  `epoch`
    /// is the caller's expected file epoch for this shard.
    fn probe(&self, id: usize, epoch: u64) -> Option<ShardView> {
        let mut slot = self.slots[id].lock().unwrap();
        // epoch-keyed invalidation: a payload admitted under a different
        // file epoch must not be served — drop it and fall through to the
        // miss path so the caller re-reads its own shard file.  (When
        // readers pinned to different epochs alternate on one shard this
        // can thrash the slot; that only happens for the shards a
        // compaction rewrote while an old-epoch session is still live,
        // and it trades a re-read for correctness.)
        if slot.data.is_some() && slot.epoch != epoch {
            if let Some(old) = slot.data.take() {
                self.used.fetch_sub(old.size(), Ordering::Relaxed);
            }
            self.stats.invalidated.fetch_add(1, Ordering::Relaxed);
        }
        let found = match &slot.data {
            Some(CacheVal::Decoded(csr)) => Some(ShardView::Decoded(csr.clone())),
            Some(CacheVal::Bytes(b)) => {
                Some(ShardView::Compressed { codec: self.codec, bytes: b.clone() })
            }
            None => None,
        };
        match found {
            Some(view) => {
                slot.referenced.store(true, Ordering::Relaxed);
                slot.hits += 1;
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(view)
            }
            None => {
                slot.misses += 1;
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Probe for shard `id` at the caller's file `epoch`; on hit, return
    /// the CSR (allocation-free for mode-1, decompressed otherwise).
    /// Decompression runs on the slot's `Arc`-shared payload *after* the
    /// slot lock is released — a slow codec never serializes other probes,
    /// and no payload copy is made.
    pub fn get(&self, id: usize, epoch: u64) -> Result<Option<Arc<Csr>>> {
        match self.probe(id, epoch) {
            Some(ShardView::Decoded(csr)) => Ok(Some(csr)),
            Some(ShardView::Compressed { codec, bytes }) => {
                let t0 = std::time::Instant::now();
                // byte codecs decode into a recycled scratch slot; the
                // structural delta-varint codec decodes straight to a CSR
                let csr = if matches!(codec, Codec::DeltaVarint) {
                    codec.decompress_shard(&bytes)?
                } else {
                    let mut buf = self.take_scratch();
                    let res = codec
                        .decompress_payload_into(&bytes, &mut buf)
                        .and_then(|()| shardfile::from_bytes(&buf));
                    self.put_scratch(buf);
                    res?
                };
                self.stats
                    .decompress_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                Ok(Some(Arc::new(csr)))
            }
            Some(ShardView::Raw(_)) => unreachable!("probe never yields Raw"),
            None => Ok(None),
        }
    }

    /// Is shard `id` currently cached at the caller's file `epoch`?  A pure
    /// peek: unlike [`Self::get`] it neither decodes nor touches the
    /// hit/miss accounting, so the governor can consult residency when
    /// building its schedule without distorting the statistics its own
    /// scores are derived from.
    pub fn is_resident(&self, id: usize, epoch: u64) -> bool {
        let slot = self.slots[id].lock().unwrap();
        slot.data.is_some() && slot.epoch == epoch
    }

    /// Lifetime (hits, misses) for shard `id` — the governor's per-shard
    /// history signal.
    pub fn shard_history(&self, id: usize) -> (u64, u64) {
        let slot = self.slots[id].lock().unwrap();
        (slot.hits, slot.misses)
    }

    /// Unused budget available for loan to the prefetch pipeline's in-flight
    /// allowance.  Shrinks as the cache fills, which is exactly how the loan
    /// is reclaimed.
    pub fn lendable_bytes(&self) -> usize {
        self.budget.saturating_sub(self.used.load(Ordering::Relaxed))
    }

    /// Install per-shard eviction priorities (higher = hotter = keep).
    /// Called by the adaptive governor each iteration; a wrong-length slice
    /// is ignored rather than panicking mid-run.
    pub fn set_priorities(&self, scores: &[u64]) {
        if scores.len() != self.slots.len() {
            return;
        }
        let mut p = self.priorities.lock().unwrap();
        p.clear();
        p.extend_from_slice(scores);
    }

    /// Insert shard `id`'s serialized payload, keyed by the file `epoch`
    /// the caller read it from — never by any cache-global notion of
    /// "current", so bytes from an old shard file can only ever be served
    /// back to readers pinned to that same epoch.  Evicts via CLOCK if
    /// over budget; gives up (rejects) if the payload alone exceeds budget.
    pub fn insert(&self, id: usize, epoch: u64, payload: &[u8]) -> Result<()> {
        let t0 = std::time::Instant::now();
        let val = if self.codec.is_compressing() {
            CacheVal::Bytes(Arc::new(self.codec.compress(payload)?))
        } else {
            CacheVal::Decoded(Arc::new(shardfile::from_bytes(payload)?))
        };
        self.stats
            .compress_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let size = val.size();
        if size > self.budget {
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        // no-evict admission (default): a full cache keeps its residents —
        // optimal under the engine's cyclic shard sweep.  CLOCK replacement
        // only when explicitly enabled.
        while self.used.load(Ordering::Relaxed) + size > self.budget {
            if !self.evict || !self.evict_one(id) {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
        }
        let mut slot = self.slots[id].lock().unwrap();
        if let Some(old) = slot.data.take() {
            self.used.fetch_sub(old.size(), Ordering::Relaxed);
        }
        self.used.fetch_add(size, Ordering::Relaxed);
        slot.data = Some(val);
        slot.epoch = epoch;
        slot.referenced.store(true, Ordering::Relaxed);
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// One-stop shard acquisition — the single entry point both the
    /// synchronous engine path and the prefetch pipeline go through:
    /// probe the cache (hit ⇒ ready-decoded buffer, no disk); on miss call
    /// `read` for the serialized payload, admit it if `admit` (budget
    /// permitting), and hand back the decoded CSR.
    pub fn fetch_decoded(
        &self,
        id: usize,
        epoch: u64,
        admit: bool,
        read: impl FnOnce() -> Result<Vec<u8>>,
    ) -> Result<Arc<Csr>> {
        if let Some(csr) = self.get(id, epoch)? {
            return Ok(csr);
        }
        let bytes = read()?;
        if admit {
            // admission failure (over budget / codec reject) is not an
            // error: the shard still decodes from the bytes in hand
            let _ = self.insert(id, epoch, &bytes);
            // mode-1 admission already decoded the payload into the slot —
            // hand that Arc back instead of decoding a second time (a plain
            // peek, no hit/miss accounting: this acquisition was already
            // counted as a miss above).  Re-check the slot's epoch: a
            // concurrent reader at another epoch may have replaced the
            // payload between our insert and this peek.
            if !self.codec.is_compressing() {
                let slot = self.slots[id].lock().unwrap();
                if slot.epoch == epoch {
                    if let Some(CacheVal::Decoded(csr)) = &slot.data {
                        return Ok(csr.clone());
                    }
                }
            }
        }
        Ok(Arc::new(shardfile::from_bytes(&bytes)?))
    }

    /// [`Self::fetch_decoded`]'s compressed-domain twin: same probe / read
    /// / admit protocol and identical hit/miss accounting, but the caller
    /// gets the cheapest faithful [`ShardView`] instead of a decoded CSR —
    /// a compressing-codec hit shares the slot payload by `Arc` (no clone,
    /// no decode), and a miss returns the serialized bytes just read for
    /// in-place walking.  Mode-1 behaves exactly like `fetch_decoded`.
    pub fn fetch_view(
        &self,
        id: usize,
        epoch: u64,
        admit: bool,
        read: impl FnOnce() -> Result<Vec<u8>>,
    ) -> Result<ShardView> {
        if let Some(view) = self.probe(id, epoch) {
            return Ok(view);
        }
        let bytes = read()?;
        if admit {
            let _ = self.insert(id, epoch, &bytes);
            if !self.codec.is_compressing() {
                let slot = self.slots[id].lock().unwrap();
                if slot.epoch == epoch {
                    if let Some(CacheVal::Decoded(csr)) = &slot.data {
                        return Ok(ShardView::Decoded(csr.clone()));
                    }
                }
            }
        }
        Ok(ShardView::Raw(Arc::new(bytes)))
    }

    /// Pick a victim and drop it; skip `protect` (the id being inserted).
    /// With governor priorities installed the coldest (lowest-priority)
    /// resident shard goes first; otherwise a CLOCK sweep (second-chance
    /// LRU approximation). Returns false if no victim exists.
    fn evict_one(&self, protect: usize) -> bool {
        let n = self.slots.len();
        // priority path: min-scan for the lowest-priority *occupied* slot
        // (O(n), no allocation — an insert may evict several times in a
        // row).  Holding the priorities lock across the scan is fine:
        // set_priorities runs once per iteration and nothing acquires the
        // locks in the opposite order.
        {
            let p = self.priorities.lock().unwrap();
            if p.len() == n {
                loop {
                    let mut best: Option<(u64, usize)> = None;
                    for i in (0..n).filter(|&i| i != protect) {
                        if best.is_some_and(|(bp, bi)| (p[i], i) >= (bp, bi)) {
                            continue;
                        }
                        if self.slots[i].lock().unwrap().data.is_some() {
                            best = Some((p[i], i));
                        }
                    }
                    let Some((_, i)) = best else {
                        return false; // nothing evictable left
                    };
                    let mut slot = self.slots[i].lock().unwrap();
                    if let Some(old) = slot.data.take() {
                        self.used.fetch_sub(old.size(), Ordering::Relaxed);
                        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                        return true;
                    }
                    // a concurrent insert/evict emptied the chosen slot
                    // between the scan and the take — rescan; occupancy
                    // only shrinks under this race, so the loop terminates
                }
            }
        }
        // CLOCK path (no priorities installed)
        for _ in 0..2 * n {
            let h = self.clock_hand.fetch_add(1, Ordering::Relaxed) % n;
            if h == protect {
                continue;
            }
            let mut slot = self.slots[h].lock().unwrap();
            if slot.data.is_none() {
                continue;
            }
            if slot.referenced.swap(false, Ordering::Relaxed) {
                continue; // second chance
            }
            let old = slot.data.take().unwrap();
            self.used.fetch_sub(old.size(), Ordering::Relaxed);
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::shardfile;

    fn shard(lo: u32, n_edges: usize) -> (Csr, Vec<u8>) {
        let edges: Vec<(u32, u32)> = (0..n_edges)
            .map(|i| ((i * 31 % 1000) as u32, lo + (i % 8) as u32))
            .collect();
        let csr = Csr::from_edges(lo, lo + 8, &edges);
        let payload = shardfile::to_bytes(&csr);
        (csr, payload)
    }

    #[test]
    fn hit_after_insert_roundtrips() {
        for codec in Codec::ALL {
            let cache = ShardCache::new(4, codec, usize::MAX);
            let (csr, payload) = shard(0, 500);
            assert!(cache.get(0, 0).unwrap().is_none());
            cache.insert(0, 0, &payload).unwrap();
            let got = cache.get(0, 0).unwrap().expect("hit");
            let mut a = got.to_edges();
            a.sort_unstable();
            let mut b = csr.to_edges();
            b.sort_unstable();
            assert_eq!(a, b, "codec {}", codec.name());
        }
    }

    #[test]
    fn budget_enforced_with_eviction() {
        let (_, payload) = shard(0, 2000);
        let one = Codec::None.compress(&payload).unwrap().len();
        // room for exactly 2 entries
        let cache = ShardCache::new(8, Codec::None, one * 2 + 10).with_eviction();
        for id in 0..6 {
            let (_, p) = shard((id * 8) as u32, 2000);
            cache.insert(id, 0, &p).unwrap();
        }
        assert!(cache.used_bytes() <= cache.budget());
        assert!(cache.num_cached() <= 2);
        assert!(cache.stats.evictions.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn no_evict_default_pins_residents() {
        let (_, payload) = shard(0, 2000);
        let one = Codec::None.compress(&payload).unwrap().len();
        let cache = ShardCache::new(8, Codec::None, one * 2 + 10);
        for id in 0..6 {
            let (_, p) = shard((id * 8) as u32, 2000);
            cache.insert(id, 0, &p).unwrap();
        }
        // first two stay, later insertions rejected — cyclic-scan-optimal
        assert_eq!(cache.num_cached(), 2);
        assert!(cache.get(0, 0).unwrap().is_some());
        assert!(cache.get(1, 0).unwrap().is_some());
        assert_eq!(cache.stats.evictions.load(Ordering::Relaxed), 0);
        assert_eq!(cache.stats.rejected.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn oversized_payload_rejected() {
        let (_, payload) = shard(0, 2000);
        let cache = ShardCache::new(2, Codec::None, 16);
        cache.insert(0, 0, &payload).unwrap();
        assert_eq!(cache.num_cached(), 0);
        assert_eq!(cache.stats.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stats_track_hits_misses() {
        let cache = ShardCache::new(2, Codec::SnapLite, usize::MAX);
        let (_, payload) = shard(0, 100);
        cache.get(0, 0).unwrap();
        cache.insert(0, 0, &payload).unwrap();
        cache.get(0, 0).unwrap();
        cache.get(1, 0).unwrap();
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 2);
        assert!((cache.stats.hit_ratio() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn fetch_decoded_hits_then_reads_once() {
        let cache = ShardCache::new(2, Codec::SnapLite, usize::MAX);
        let (csr, payload) = shard(0, 400);
        let reads = AtomicU64::new(0);
        let fetch = |cache: &ShardCache| {
            cache
                .fetch_decoded(0, 0, true, || {
                    reads.fetch_add(1, Ordering::Relaxed);
                    Ok(payload.clone())
                })
                .unwrap()
        };
        let a = fetch(&cache);
        assert_eq!(reads.load(Ordering::Relaxed), 1, "miss must read");
        let b = fetch(&cache);
        assert_eq!(reads.load(Ordering::Relaxed), 1, "hit must not read");
        let mut x = a.to_edges();
        x.sort_unstable();
        let mut y = csr.to_edges();
        y.sort_unstable();
        let mut z = b.to_edges();
        z.sort_unstable();
        assert_eq!(x, y);
        assert_eq!(x, z);
    }

    #[test]
    fn fetch_decoded_without_admission_rereads() {
        let cache = ShardCache::new(2, Codec::None, usize::MAX);
        let (_, payload) = shard(0, 100);
        let reads = AtomicU64::new(0);
        for _ in 0..3 {
            cache
                .fetch_decoded(0, 0, false, || {
                    reads.fetch_add(1, Ordering::Relaxed);
                    Ok(payload.clone())
                })
                .unwrap();
        }
        assert_eq!(reads.load(Ordering::Relaxed), 3);
        assert_eq!(cache.num_cached(), 0);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn fetch_view_shares_slot_bytes_without_cloning() {
        let cache = ShardCache::new(2, Codec::SnapLite, usize::MAX);
        let (csr, payload) = shard(0, 400);
        let reads = AtomicU64::new(0);
        // miss: serialized bytes come back raw, one read
        let v = cache
            .fetch_view(0, 0, true, || {
                reads.fetch_add(1, Ordering::Relaxed);
                Ok(payload.clone())
            })
            .unwrap();
        match v {
            ShardView::Raw(bytes) => assert_eq!(*bytes, payload),
            _ => panic!("miss must return the raw read"),
        }
        assert_eq!(reads.load(Ordering::Relaxed), 1);
        // hit: the compressed slot payload, Arc-shared with the slot
        let v = cache.fetch_view(0, 0, true, || panic!("hit must not read")).unwrap();
        match v {
            ShardView::Compressed { codec, bytes } => {
                assert_eq!(codec, Codec::SnapLite);
                assert!(Arc::strong_count(&bytes) >= 2, "payload must be shared, not cloned");
                let mut a = codec.decompress_shard(&bytes).unwrap().to_edges();
                a.sort_unstable();
                let mut b = csr.to_edges();
                b.sort_unstable();
                assert_eq!(a, b);
            }
            _ => panic!("compressing-codec hit must return the slot bytes"),
        }
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fetch_view_mode1_matches_fetch_decoded() {
        let cache = ShardCache::new(2, Codec::None, usize::MAX);
        let (_, payload) = shard(0, 100);
        // admission decodes into the slot; the view is that same Arc
        let v = cache.fetch_view(0, 0, true, || Ok(payload.clone())).unwrap();
        let ShardView::Decoded(a) = v else { panic!("mode-1 admit must yield Decoded") };
        let ShardView::Decoded(b) = cache.fetch_view(0, 0, true, || panic!("hit")).unwrap() else {
            panic!("mode-1 hit must yield Decoded")
        };
        assert!(Arc::ptr_eq(&a, &b), "both views must share the cached Arc");
        // without admission the raw bytes come back
        let nc = ShardCache::new(2, Codec::None, usize::MAX);
        match nc.fetch_view(0, 0, false, || Ok(payload.clone())).unwrap() {
            ShardView::Raw(bytes) => assert_eq!(*bytes, payload),
            _ => panic!("unadmitted read must stay raw"),
        }
        assert_eq!(nc.num_cached(), 0);
    }

    #[test]
    fn compressed_hits_recycle_one_decode_scratch() {
        let cache = ShardCache::new(2, Codec::Zlib1, usize::MAX);
        let (csr, payload) = shard(0, 400);
        cache.insert(0, 0, &payload).unwrap();
        for _ in 0..3 {
            let got = cache.get(0, 0).unwrap().expect("hit");
            let mut a = got.to_edges();
            a.sort_unstable();
            let mut b = csr.to_edges();
            b.sort_unstable();
            assert_eq!(a, b);
        }
        assert_eq!(
            cache.scratch.lock().unwrap().len(),
            1,
            "sequential hits must reuse one scratch buffer, not grow the pool"
        );
        // delta-varint decodes structurally — the scratch pool stays out of it
        let dv = ShardCache::new(1, Codec::DeltaVarint, usize::MAX);
        dv.insert(0, 0, &payload).unwrap();
        assert!(dv.get(0, 0).unwrap().is_some());
        assert!(dv.scratch.lock().unwrap().is_empty());
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(ShardCache::new(16, Codec::SnapLite, 1 << 20));
        let payloads: Vec<Vec<u8>> = (0..16).map(|i| shard((i * 8) as u32, 300).1).collect();
        std::thread::scope(|s| {
            for t in 0..4 {
                let cache = cache.clone();
                let payloads = &payloads;
                s.spawn(move || {
                    for round in 0..50 {
                        let id = (t * 7 + round) % 16;
                        if cache.get(id, 0).unwrap().is_none() {
                            cache.insert(id, 0, &payloads[id]).unwrap();
                        }
                    }
                });
            }
        });
        assert!(cache.used_bytes() <= 1 << 20);
    }

    #[test]
    fn residency_peek_and_history_do_not_touch_stats() {
        let cache = ShardCache::new(2, Codec::None, usize::MAX);
        let (_, payload) = shard(0, 100);
        assert!(!cache.is_resident(0, 0));
        cache.insert(0, 0, &payload).unwrap();
        assert!(cache.is_resident(0, 0));
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 0);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 0);
        assert_eq!(cache.shard_history(0), (0, 0));
        cache.get(0, 0).unwrap();
        cache.get(1, 0).unwrap();
        cache.get(1, 0).unwrap();
        assert_eq!(cache.shard_history(0), (1, 0));
        assert_eq!(cache.shard_history(1), (0, 2));
    }

    #[test]
    fn epoch_mismatch_invalidates_stale_slots_lazily() {
        let cache = ShardCache::new(2, Codec::SnapLite, usize::MAX);
        let (_, payload) = shard(0, 300);
        cache.insert(0, 0, &payload).unwrap();
        cache.insert(1, 0, &payload).unwrap();
        assert!(cache.is_resident(0, 0));
        let used_full = cache.used_bytes();
        // shard 0's file was rewritten (compaction): a reader pinned to the
        // new snapshot expects file epoch 1 for it
        assert!(!cache.is_resident(0, 1), "stale slot must not read as resident");
        assert!(cache.is_resident(1, 0), "untouched shard keeps its slot");
        // the mismatched probe drops the slot and reports a miss
        assert!(cache.get(0, 1).unwrap().is_none());
        assert_eq!(cache.stats.invalidated.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        assert!(cache.used_bytes() < used_full, "invalidation must return budget");
        // re-admission records the new epoch and hits again
        cache.insert(0, 1, &payload).unwrap();
        assert!(cache.is_resident(0, 1));
        assert!(cache.get(0, 1).unwrap().is_some());
        // fetch paths observe the invalidation too
        let cache = ShardCache::new(1, Codec::None, usize::MAX);
        let reads = AtomicU64::new(0);
        let fetch = |cache: &ShardCache, epoch: u64| {
            cache
                .fetch_decoded(0, epoch, true, || {
                    reads.fetch_add(1, Ordering::Relaxed);
                    Ok(payload.clone())
                })
                .unwrap()
        };
        fetch(&cache, 0);
        fetch(&cache, 0);
        assert_eq!(reads.load(Ordering::Relaxed), 1);
        fetch(&cache, 7);
        assert_eq!(reads.load(Ordering::Relaxed), 2, "stale slot must force a re-read");
        fetch(&cache, 7);
        assert_eq!(reads.load(Ordering::Relaxed), 2, "re-admitted slot hits under new epoch");
    }

    #[test]
    fn concurrent_readers_at_different_epochs_never_cross_serve() {
        // Two generations of shard 0's file: the epoch-0 payload has 100
        // edges, the epoch-1 (compacted) payload 200.  Readers pinned to
        // each epoch hammer the same slot concurrently; an epoch-keyed hit
        // must always decode to the reader's own generation — the
        // cross-epoch stale-serve this refactor eliminates would surface
        // here as a wrong edge count.
        let old_payload = shard(0, 100).1;
        let new_payload = shard(0, 200).1;
        for codec in [Codec::None, Codec::SnapLite] {
            let cache = Arc::new(ShardCache::new(1, codec, usize::MAX));
            std::thread::scope(|s| {
                for t in 0..4 {
                    let cache = cache.clone();
                    let (epoch, mine, want) = if t % 2 == 0 {
                        (0u64, &old_payload, 100)
                    } else {
                        (1u64, &new_payload, 200)
                    };
                    s.spawn(move || {
                        for _ in 0..200 {
                            let csr = cache
                                .fetch_decoded(0, epoch, true, || Ok(mine.clone()))
                                .unwrap();
                            assert_eq!(
                                csr.num_edges(),
                                want,
                                "epoch-{epoch} reader served the other epoch's payload"
                            );
                        }
                    });
                }
            });
        }
    }

    #[test]
    fn lendable_bytes_shrink_as_cache_fills() {
        let (_, payload) = shard(0, 500);
        let one = Codec::None.compress(&payload).unwrap().len();
        let cache = ShardCache::new(4, Codec::None, one * 4);
        assert_eq!(cache.lendable_bytes(), one * 4);
        cache.insert(0, 0, &payload).unwrap();
        let after_one = cache.lendable_bytes();
        assert!(after_one < one * 4);
        cache.insert(1, 0, &payload).unwrap();
        assert!(cache.lendable_bytes() < after_one);
        // unbounded budget: effectively infinite loan
        let unbounded = ShardCache::new(2, Codec::None, usize::MAX);
        unbounded.insert(0, 0, &payload).unwrap();
        assert!(unbounded.lendable_bytes() > (1 << 40));
    }

    #[test]
    fn eviction_prefers_low_priority_when_scores_installed() {
        let (_, payload) = shard(0, 2000);
        let one = Codec::None.compress(&payload).unwrap().len();
        // room for exactly 2 entries
        let cache = ShardCache::new(4, Codec::None, one * 2 + 10).with_eviction();
        cache.insert(0, 0, &payload).unwrap();
        cache.insert(1, 0, &payload).unwrap();
        // shard 0 is hot (priority 100), shard 1 cold (priority 1)
        cache.set_priorities(&[100, 1, 50, 50]);
        let (_, p2) = shard(16, 2000);
        cache.insert(2, 0, &p2).unwrap();
        assert!(cache.is_resident(0, 0), "hot shard must survive eviction");
        assert!(!cache.is_resident(1, 0), "cold shard must be the victim");
        assert!(cache.is_resident(2, 0));
        // a wrong-length priority slice is ignored (previous scores stand)
        cache.set_priorities(&[1, 2]);
        let (_, p3) = shard(24, 2000);
        cache.insert(3, 0, &p3).unwrap();
        assert!(cache.used_bytes() <= cache.budget());
    }
}
