//! Codec abstraction over the cache's compression modes.

use std::io::{Read, Write};
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::graph::csr::Csr;

/// Compression codecs available to the shard cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// mode-1: no compression.
    None,
    /// mode-2: hand-rolled snappy-class LZ77 (see [`super::snaplite`]).
    SnapLite,
    /// mode-3: zlib level 1.
    Zlib1,
    /// mode-4: zlib level 3.
    Zlib3,
    /// extension: zstd level 1.
    Zstd1,
    /// extension: CSR-aware delta-varint (see [`super::deltavarint`]).
    DeltaVarint,
}

/// Paper naming: mode-1 … mode-4 (plus extensions).
pub type CacheMode = Codec;

impl Codec {
    /// The paper's four modes, in order.
    pub const PAPER_MODES: [Codec; 4] = [Codec::None, Codec::SnapLite, Codec::Zlib1, Codec::Zlib3];

    /// All codecs (for ablations).
    pub const ALL: [Codec; 6] = [
        Codec::None,
        Codec::SnapLite,
        Codec::Zlib1,
        Codec::Zlib3,
        Codec::Zstd1,
        Codec::DeltaVarint,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::SnapLite => "snaplite",
            Codec::Zlib1 => "zlib-1",
            Codec::Zlib3 => "zlib-3",
            Codec::Zstd1 => "zstd-1",
            Codec::DeltaVarint => "delta-varint",
        }
    }

    /// Paper mode number (1-4), extensions get 5+.
    pub fn mode_number(&self) -> u8 {
        match self {
            Codec::None => 1,
            Codec::SnapLite => 2,
            Codec::Zlib1 => 3,
            Codec::Zlib3 => 4,
            Codec::Zstd1 => 5,
            Codec::DeltaVarint => 6,
        }
    }

    /// Compress an already-serialized shard payload.  `DeltaVarint` is
    /// CSR-structural, so it re-parses the payload; all other codecs are
    /// byte-oriented.
    pub fn compress(&self, payload: &[u8]) -> Result<Vec<u8>> {
        Ok(match self {
            Codec::None => payload.to_vec(),
            Codec::SnapLite => super::snaplite::compress(payload),
            Codec::Zlib1 | Codec::Zlib3 => {
                let level = if *self == Codec::Zlib1 { 1 } else { 3 };
                let mut enc = flate2::write::ZlibEncoder::new(
                    Vec::with_capacity(payload.len() / 2),
                    flate2::Compression::new(level),
                );
                enc.write_all(payload)?;
                enc.finish()?
            }
            Codec::Zstd1 => zstd::bulk::compress(payload, 1).context("zstd compress")?,
            Codec::DeltaVarint => {
                let csr = crate::storage::shardfile::from_bytes(payload)
                    .context("delta-varint needs a CSR shard payload")?;
                super::deltavarint::encode(&csr)
            }
        })
    }

    /// Invert [`Self::compress`].
    pub fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(match self {
            Codec::None => data.to_vec(),
            Codec::SnapLite => super::snaplite::decompress(data)?,
            Codec::Zlib1 | Codec::Zlib3 => {
                let mut dec = flate2::read::ZlibDecoder::new(data);
                let mut out = Vec::new();
                dec.read_to_end(&mut out)?;
                out
            }
            Codec::Zstd1 => {
                zstd::bulk::decompress(data, 1 << 30).context("zstd decompress")?
            }
            Codec::DeltaVarint => {
                let csr = super::deltavarint::decode(data)?;
                crate::storage::shardfile::to_bytes(&csr)
            }
        })
    }

    /// Convenience: decompress directly to a CSR shard.
    pub fn decompress_shard(&self, data: &[u8]) -> Result<Csr> {
        match self {
            Codec::DeltaVarint => super::deltavarint::decode(data),
            _ => crate::storage::shardfile::from_bytes(&self.decompress(data)?),
        }
    }

    /// Does a cache slot under this codec hold transformed bytes (true) or
    /// the decoded shard itself (false, mode-1)?
    pub fn is_compressing(&self) -> bool {
        *self != Codec::None
    }

    /// Byte-codec decompression into a caller-owned scratch buffer
    /// (cleared first) — the compressed-domain gather path's decode step,
    /// reusing one allocation per worker across shards.  `DeltaVarint` is
    /// structural, not byte-oriented: walk it with
    /// [`super::deltavarint::plan`]/`DvCursor` instead.
    pub fn decompress_payload_into(&self, data: &[u8], out: &mut Vec<u8>) -> Result<()> {
        match self {
            Codec::None => {
                out.clear();
                out.extend_from_slice(data);
            }
            Codec::SnapLite => super::snaplite::decompress_into(data, out)?,
            Codec::Zlib1 | Codec::Zlib3 => {
                out.clear();
                let mut dec = flate2::read::ZlibDecoder::new(data);
                dec.read_to_end(out)?;
            }
            Codec::Zstd1 => {
                // the vendored shim's bulk API allocates internally; copy
                // into the scratch so the caller's reuse contract holds
                let v = zstd::bulk::decompress(data, 1 << 30).context("zstd decompress")?;
                out.clear();
                out.extend_from_slice(&v);
            }
            Codec::DeltaVarint => {
                bail!("delta-varint payloads are walked structurally, not byte-decompressed")
            }
        }
        Ok(())
    }
}

impl FromStr for Codec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "mode-1" | "1" => Codec::None,
            "snaplite" | "snappy" | "mode-2" | "2" => Codec::SnapLite,
            "zlib-1" | "zlib1" | "mode-3" | "3" => Codec::Zlib1,
            "zlib-3" | "zlib3" | "mode-4" | "4" => Codec::Zlib3,
            "zstd-1" | "zstd" | "mode-5" | "5" => Codec::Zstd1,
            "delta-varint" | "deltavarint" | "dv" | "mode-6" | "6" => Codec::DeltaVarint,
            other => bail!("unknown codec {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn shard_payload() -> Vec<u8> {
        let edges = generator::rmat(10, 8000, generator::RmatParams::default(), 2);
        let in_range: Vec<_> = edges.into_iter().filter(|&(_, d)| d < 512).collect();
        let csr = Csr::from_edges(0, 512, &in_range);
        crate::storage::shardfile::to_bytes(&csr)
    }

    #[test]
    fn all_codecs_roundtrip_shard_payload() {
        let payload = shard_payload();
        for codec in Codec::ALL {
            let c = codec.compress(&payload).unwrap();
            let shard = codec.decompress_shard(&c).unwrap();
            shard.validate().unwrap();
            // DeltaVarint normalizes row order; compare edge multisets
            let mut a = shard.to_edges();
            a.sort_unstable();
            let mut b = crate::storage::shardfile::from_bytes(&payload).unwrap().to_edges();
            b.sort_unstable();
            assert_eq!(a, b, "codec {}", codec.name());
        }
    }

    #[test]
    fn compressing_codecs_shrink_shards() {
        let payload = shard_payload();
        let codecs =
            [Codec::SnapLite, Codec::Zlib1, Codec::Zlib3, Codec::Zstd1, Codec::DeltaVarint];
        for codec in codecs {
            let c = codec.compress(&payload).unwrap();
            assert!(
                c.len() < payload.len(),
                "{} did not compress: {} vs {}",
                codec.name(),
                c.len(),
                payload.len()
            );
        }
    }

    #[test]
    fn ratio_ordering_roughly_matches_paper() {
        // mode-4 (zlib-3) should compress at least as well as mode-2
        let payload = shard_payload();
        let m2 = Codec::SnapLite.compress(&payload).unwrap().len();
        let m4 = Codec::Zlib3.compress(&payload).unwrap().len();
        assert!(m4 <= m2, "zlib-3 {m4} vs snaplite {m2}");
    }

    #[test]
    fn payload_scratch_decode_matches_decompress() {
        let payload = shard_payload();
        let mut scratch = Vec::new();
        for codec in [Codec::None, Codec::SnapLite, Codec::Zlib1, Codec::Zlib3, Codec::Zstd1] {
            let c = codec.compress(&payload).unwrap();
            codec.decompress_payload_into(&c, &mut scratch).unwrap();
            assert_eq!(scratch, codec.decompress(&c).unwrap(), "codec {}", codec.name());
            assert_eq!(scratch, payload, "codec {}", codec.name());
        }
        let dv = Codec::DeltaVarint.compress(&payload).unwrap();
        assert!(Codec::DeltaVarint.decompress_payload_into(&dv, &mut scratch).is_err());
        assert!(Codec::DeltaVarint.is_compressing() && !Codec::None.is_compressing());
    }

    #[test]
    fn from_str_aliases() {
        assert_eq!("mode-2".parse::<Codec>().unwrap(), Codec::SnapLite);
        assert_eq!("zlib-3".parse::<Codec>().unwrap(), Codec::Zlib3);
        assert!("nope".parse::<Codec>().is_err());
    }
}
