//! `graphmp serve` — the resident multi-tenant engine.
//!
//! Opening a big dataset costs seconds to minutes (degree arrays, Bloom
//! filters, cache warming); paying that per CLI invocation makes
//! interactive use of a semi-external engine pointless.  The server keeps
//! one [`VswEngine`] resident per dataset and speaks a line protocol
//! ([`protocol`]) over localhost TCP and (on Unix) a Unix-domain socket —
//! vendored end to end, no network dependencies.
//!
//! Three properties define the design:
//!
//! * **Epoch-pinned sessions** ([`session`]): `open` captures the
//!   engine's current [`EpochState`] Arc; every `run`/`value`/`degree` on
//!   that session reads that snapshot bit-identically, no matter how many
//!   `ingest` requests advance the manifest underneath.  A new `open`
//!   after an ingest sees the new epoch.  Pinning is structural — the
//!   session holds the snapshot, there is nothing to forget to check.
//! * **Admission control** ([`scheduler`]): heavy jobs (`run`, `ingest`,
//!   first-touch engine loads) are capped at a small concurrency with a
//!   bounded wait queue; light lookups have their own generous cap so
//!   they never starve behind heavy work.  Queue overflow answers
//!   `err busy` immediately.
//! * **Serialized mutation**: per dataset, ingests take an exclusive lock
//!   and then [`VswEngine::refresh_latest`] — concurrent readers are
//!   never blocked, they just keep their epoch.

mod protocol;
mod scheduler;
mod session;

pub use protocol::{part, Request, Response};
pub use scheduler::{JobClass, Scheduler, SchedulerConfig};
pub use session::{Session, SessionRegistry};

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::apps;
use crate::cache::Codec;
use crate::engine::{EngineConfig, VswEngine};
use crate::graph::mutation;
use crate::storage::{delta, DatasetDir};

/// One resident dataset: the shared engine plus the mutation lock that
/// serializes `ingest`/`refresh` against each other (readers never take
/// it).
struct EngineEntry {
    dir: DatasetDir,
    engine: VswEngine,
    ingest_lock: Mutex<()>,
    /// Server-clock (ms) of the last `engine_entry` resolution, for
    /// `--engine-ttl-secs` idle eviction.
    last_used_ms: AtomicU64,
}

/// Where to poke a blocking accept loop so it re-checks the shutdown
/// flag.
enum WakeAddr {
    Tcp(std::net::SocketAddr),
    #[cfg(unix)]
    Unix(PathBuf),
}

/// The daemon state behind every connection thread.
pub struct Server {
    ecfg: EngineConfig,
    engines: Mutex<HashMap<PathBuf, Arc<EngineEntry>>>,
    sessions: SessionRegistry,
    sched: Scheduler,
    shutdown: AtomicBool,
    wakers: Mutex<Vec<WakeAddr>>,
    /// Idle-*engine* TTL (`--engine-ttl-secs`; `None` = never evict).
    engine_ttl: Option<Duration>,
    /// Server clock origin for the engine last-used stamps.
    t0: Instant,
}

impl Server {
    /// `ecfg` is fixed for the daemon's lifetime and applies to every
    /// dataset it opens — pass the same engine flags to `serve` as to the
    /// `run` invocations you want to compare against.  An explicit
    /// `--epoch` pin is rejected: the daemon's whole point is serving the
    /// advancing latest epoch while sessions pin themselves.
    pub fn new(ecfg: EngineConfig, sched: SchedulerConfig) -> Result<Self> {
        anyhow::ensure!(
            ecfg.epoch.is_none(),
            "serve refuses --epoch: sessions pin epochs, the daemon follows the latest"
        );
        Ok(Self {
            ecfg,
            engines: Mutex::new(HashMap::new()),
            sessions: SessionRegistry::with_ttl(Some(Self::DEFAULT_SESSION_TTL)),
            sched: Scheduler::new(sched),
            shutdown: AtomicBool::new(false),
            wakers: Mutex::new(Vec::new()),
            engine_ttl: None,
            t0: Instant::now(),
        })
    }

    /// Sessions idle this long are evicted (`--session-ttl-secs`; 0
    /// disables).  A pinned snapshot holds real memory — old-epoch shards,
    /// stored fixpoints — so an abandoned session must eventually let go.
    pub const DEFAULT_SESSION_TTL: Duration = Duration::from_secs(3600);

    /// Replace the idle-session TTL (`None` = never evict).  Call before
    /// serving: the registry is rebuilt, dropping any existing sessions.
    pub fn with_session_ttl(mut self, ttl: Option<Duration>) -> Self {
        self.sessions = SessionRegistry::with_ttl(ttl);
        self
    }

    /// Set the idle-*engine* TTL (`--engine-ttl-secs`; `None` = engines
    /// stay resident forever).  An engine is evicted only when it has
    /// been unused past the TTL *and* no live session still pins its
    /// dataset — a pinned snapshot must keep resolving against the same
    /// resident cache.
    pub fn with_engine_ttl(mut self, ttl: Option<Duration>) -> Self {
        self.engine_ttl = ttl;
        self
    }

    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Resolve (or first-touch load) the resident engine for `data`.
    /// Loading counts as a heavy job; a map hit is free.
    fn engine_entry(&self, data: &str) -> Result<Arc<EngineEntry>> {
        let dir = DatasetDir::new(data);
        anyhow::ensure!(dir.exists(), "{} is not a preprocessed dataset", dir.root.display());
        let key = std::fs::canonicalize(&dir.root).unwrap_or_else(|_| dir.root.clone());
        if let Some(e) = self.engines.lock().unwrap().get(&key) {
            e.last_used_ms.store(self.now_ms(), Ordering::Relaxed);
            return Ok(e.clone());
        }
        let _ticket = self.sched.admit(JobClass::Heavy)?;
        // the map lock is held across the load so a racing open of the
        // same dataset waits for this one instead of loading twice
        let mut map = self.engines.lock().unwrap();
        if let Some(e) = map.get(&key) {
            e.last_used_ms.store(self.now_ms(), Ordering::Relaxed);
            return Ok(e.clone());
        }
        let dir = DatasetDir::new(&key);
        let engine = VswEngine::open(dir.clone(), self.ecfg.clone())
            .with_context(|| format!("opening {}", key.display()))?;
        let entry = Arc::new(EngineEntry {
            dir,
            engine,
            ingest_lock: Mutex::new(()),
            last_used_ms: AtomicU64::new(self.now_ms()),
        });
        map.insert(key, entry.clone());
        Ok(entry)
    }

    /// Evict engines idle past `--engine-ttl-secs`.  Runs on every
    /// dispatch and on the sweeper's timer tick (so eviction needs zero
    /// further requests).  An engine survives while any clone of its
    /// entry is in use (a run in flight) or any live session still pins
    /// its dataset.  Returns the number evicted.
    pub fn sweep_idle_engines(&self) -> usize {
        let Some(ttl) = self.engine_ttl else { return 0 };
        let now = self.now_ms();
        let ttl_ms = ttl.as_millis() as u64;
        let mut evicted = 0usize;
        {
            let mut map = self.engines.lock().unwrap();
            map.retain(|_, entry| {
                let idle =
                    now.saturating_sub(entry.last_used_ms.load(Ordering::Relaxed)) > ttl_ms;
                let keep = !idle
                    || Arc::strong_count(entry) > 1
                    || self.sessions.references(&entry.dir.root);
                if !keep {
                    evicted += 1;
                }
                keep
            });
        }
        if evicted > 0 {
            crate::obs::metrics::counter_add(
                "graphmp_engines_evicted_total",
                &[],
                evicted as u64,
            );
            crate::obs::metrics::gauge_set(
                "graphmp_engines_resident",
                &[],
                self.engines.lock().unwrap().len() as u64,
            );
        }
        evicted
    }

    /// Handle one request line, producing exactly one response.  Pure
    /// request/response — no connection state — so unit tests drive the
    /// full command surface without a socket.
    pub fn handle(&self, line: &str) -> Response {
        let req = match protocol::handle_malformed(line) {
            Ok(r) => r,
            Err(resp) => return resp,
        };
        match self.dispatch(&req) {
            Ok(resp) => resp,
            Err(e) => Response::err(format!("{e:#}")),
        }
    }

    /// Verbs counted per-label in `graphmp_requests_total`; anything else
    /// folds into `verb="unknown"` so a misbehaving client cannot mint
    /// unbounded label cardinality.
    const VERBS: &'static [&'static str] = &[
        "ping", "open", "close", "info", "epoch", "refresh", "stats", "metrics", "run", "value",
        "degree", "ingest", "watch", "poll", "shutdown",
    ];

    fn dispatch(&self, req: &Request) -> Result<Response> {
        let verb = if Self::VERBS.contains(&req.cmd.as_str()) { req.cmd.as_str() } else { "unknown" };
        crate::obs::metrics::counter_add("graphmp_requests_total", &[("verb", verb)], 1);
        // opportunistic idle-session eviction: every request pays one
        // cheap map scan, so an abandoned session outlives its TTL by at
        // most the daemon's idle gap between requests
        self.sessions.sweep_idle();
        self.sweep_idle_engines();
        match req.cmd.as_str() {
            "ping" => Ok(Response::ok().with("pong", 1)),
            "open" => self.cmd_open(req),
            "close" => self.cmd_close(req),
            "info" => self.cmd_info(req),
            "epoch" => self.cmd_epoch(req),
            "refresh" => self.cmd_refresh(req),
            "stats" => Ok(self.cmd_stats()),
            "metrics" => Ok(self.cmd_metrics()),
            "run" => self.cmd_run(req),
            "value" => self.cmd_value(req),
            "degree" => self.cmd_degree(req),
            "ingest" => self.cmd_ingest(req),
            "watch" => self.cmd_watch(req, true),
            "poll" => self.cmd_watch(req, false),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                self.wake_listeners();
                Ok(Response::ok().with("bye", 1))
            }
            other => bail!("unknown command {other:?}"),
        }
    }

    fn cmd_open(&self, req: &Request) -> Result<Response> {
        let entry = self.engine_entry(req.req("data")?)?;
        let state = entry.engine.snapshot();
        let session = self.sessions.open(entry.dir.root.clone(), state);
        let st = &session.state;
        Ok(Response::ok()
            .with("session", session.id)
            .with("epoch", st.epoch)
            .with("vertices", st.property.info.num_vertices)
            .with("edges", st.property.info.num_edges)
            .with("shards", st.property.num_shards()))
    }

    fn cmd_close(&self, req: &Request) -> Result<Response> {
        let id = req.req_u64("session")?;
        Ok(Response::ok().with("closed", u8::from(self.sessions.close(id))))
    }

    fn cmd_info(&self, req: &Request) -> Result<Response> {
        let _ticket = self.sched.admit(JobClass::Light)?;
        // session → the pinned snapshot; data → the engine's current epoch
        let (name, st) = match req.get_u64("session")? {
            Some(id) => {
                let s = self.sessions.get(id)?;
                (s.state.property.name.clone(), s.state.clone())
            }
            None => {
                let entry = self.engine_entry(req.req("data")?)?;
                let st = entry.engine.snapshot();
                (st.property.name.clone(), st)
            }
        };
        Ok(Response::ok()
            .with("name", name)
            .with("epoch", st.epoch)
            .with("vertices", st.property.info.num_vertices)
            .with("edges", st.property.info.num_edges)
            .with("shards", st.property.num_shards()))
    }

    fn cmd_epoch(&self, req: &Request) -> Result<Response> {
        let entry = self.engine_entry(req.req("data")?)?;
        Ok(Response::ok().with("epoch", entry.engine.epoch()))
    }

    /// Re-resolve the latest epoch after an out-of-band mutation (e.g. a
    /// CLI `ingest` run against the same files while the daemon is up).
    fn cmd_refresh(&self, req: &Request) -> Result<Response> {
        let entry = self.engine_entry(req.req("data")?)?;
        let _guard = entry.ingest_lock.lock().unwrap();
        let epoch = entry.engine.refresh_latest()?;
        Ok(Response::ok().with("epoch", epoch))
    }

    fn cmd_stats(&self) -> Response {
        // deliberately unthrottled: this is how saturation is observed
        let (light, heavy, queued) = self.sched.counts();
        // aggregate direct-I/O traffic across every resident engine —
        // until now uring::counts() was computed but invisible here
        let (mut direct, mut fallback) = (0u64, 0u64);
        for e in self.engines.lock().unwrap().values() {
            if let Some((d, f)) = e.engine.direct_counts() {
                direct += d;
                fallback += f;
            }
        }
        Response::ok()
            .with("sessions", self.sessions.count())
            .with("datasets", self.engines.lock().unwrap().len())
            .with("light", light)
            .with("heavy", heavy)
            .with("queued", queued)
            .with("simd", crate::engine::simd::level())
            .with("uring", crate::storage::uring::resolve_mode().name())
            .with("direct_reads", direct)
            .with("fallback_reads", fallback)
    }

    /// The Prometheus exposition with daemon-level gauges refreshed at
    /// scrape time (sessions, resident engines, admission state).
    pub fn metrics_text(&self) -> String {
        use crate::obs::metrics as m;
        let (light, heavy, queued) = self.sched.counts();
        m::gauge_set("graphmp_sessions_open", &[], self.sessions.count() as u64);
        m::gauge_set(
            "graphmp_engines_resident",
            &[],
            self.engines.lock().unwrap().len() as u64,
        );
        m::gauge_set("graphmp_jobs_inflight", &[("class", "light")], light as u64);
        m::gauge_set("graphmp_jobs_inflight", &[("class", "heavy")], heavy as u64);
        m::gauge_set("graphmp_jobs_queued", &[], queued as u64);
        m::render()
    }

    /// `metrics` verb: the exposition rides the line protocol as raw
    /// payload lines, so `graphmp client metrics` is a one-shot scrape.
    fn cmd_metrics(&self) -> Response {
        let text = self.metrics_text();
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        Response::ok().with("format", "prometheus-0.0.4").with_payload(lines)
    }

    /// Per-request engine-config overrides on `run`: `iters=`, `threads=`
    /// and `codec=` shadow the daemon's fixed config for this one request.
    /// Returns `None` when the request carries no overrides (the resident
    /// engine runs untouched); malformed values become `err` responses.
    fn run_overrides(&self, req: &Request) -> Result<Option<EngineConfig>> {
        let (iters, threads, codec) =
            (req.get("iters"), req.get("threads"), req.get("codec"));
        if iters.is_none() && threads.is_none() && codec.is_none() {
            return Ok(None);
        }
        let mut cfg = self.ecfg.clone();
        if let Some(v) = iters {
            cfg.max_iters =
                v.parse().with_context(|| format!("run: bad iters={v:?}"))?;
        }
        if let Some(v) = threads {
            cfg.threads =
                v.parse().with_context(|| format!("run: bad threads={v:?}"))?;
            anyhow::ensure!(cfg.threads > 0, "run: threads=0 is not an engine");
        }
        if let Some(v) = codec {
            cfg.cache_codec = v
                .parse::<Codec>()
                .map_err(|e| e.context(format!("run: bad codec={v:?}")))?;
        }
        Ok(Some(cfg))
    }

    fn cmd_run(&self, req: &Request) -> Result<Response> {
        let sid = req.req_u64("session")?;
        let session = self.sessions.get(sid)?;
        let app = apps::by_name(req.req("app")?)?;
        let overrides = self.run_overrides(req)?;
        let entry = self.engine_entry(&session.dataset.display().to_string())?;
        let _ticket = self.sched.admit(JobClass::Heavy)?;
        let t0 = Instant::now();
        let result = match overrides {
            // a shadow engine over the same dataset + pinned snapshot:
            // shares the resident shard cache when compatible, runs this
            // one request, drops
            Some(cfg) => entry.engine.with_config(cfg)?.run_any_pinned(&session.state, &app)?,
            None => entry.engine.run_any_pinned(&session.state, &app)?,
        };
        let values = Arc::new(result.values);
        session.store_result(app.name(), values.clone());
        let mut resp = Response::ok()
            .with("session", sid)
            .with("app", app.name())
            .with("epoch", session.state.epoch)
            .with("iters", result.stats.num_iters())
            .with("vertices", values.len())
            .with("wall_us", t0.elapsed().as_micros());
        if req.get("values") == Some("1") {
            let lines = (0..values.len())
                .map(|i| values.render_bits(i).expect("index in range"))
                .collect();
            resp = resp.with_payload(lines);
        }
        Ok(resp)
    }

    fn cmd_value(&self, req: &Request) -> Result<Response> {
        let _ticket = self.sched.admit(JobClass::Light)?;
        let session = self.sessions.get(req.req_u64("session")?)?;
        let app = req.req("app")?;
        let vertex = req.req_u64("vertex")? as usize;
        let values = session
            .result(app)
            .with_context(|| format!("no {app} values in session {} (run first)", session.id))?;
        let bits = values
            .render_bits(vertex)
            .with_context(|| format!("vertex {vertex} out of range ({})", values.len()))?;
        Ok(Response::ok()
            .with("session", session.id)
            .with("app", app)
            .with("vertex", vertex)
            .with("value", bits))
    }

    fn cmd_degree(&self, req: &Request) -> Result<Response> {
        let _ticket = self.sched.admit(JobClass::Light)?;
        let session = self.sessions.get(req.req_u64("session")?)?;
        let vertex = req.req_u64("vertex")? as usize;
        let deg = &session.state.vertex_info.degrees;
        anyhow::ensure!(vertex < deg.in_deg.len(), "vertex {vertex} out of range");
        Ok(Response::ok()
            .with("session", session.id)
            .with("vertex", vertex)
            .with("in", deg.in_deg[vertex])
            .with("out", deg.out_deg[vertex]))
    }

    fn cmd_ingest(&self, req: &Request) -> Result<Response> {
        let entry = self.engine_entry(req.req("data")?)?;
        let batch_path = PathBuf::from(req.req("batch")?);
        let batch = delta::load_log_auto(&batch_path)
            .with_context(|| format!("reading mutation batch {}", batch_path.display()))?;
        let fpr = match req.get("bloom-fpr") {
            Some(v) => v.parse::<f64>().context("bad bloom-fpr")?,
            None => 0.01,
        };
        let _ticket = self.sched.admit(JobClass::Heavy)?;
        let _guard = entry.ingest_lock.lock().unwrap();
        let report = mutation::ingest(&entry.dir, &batch, fpr)?;
        let epoch = entry.engine.refresh_latest()?;
        Ok(Response::ok()
            .with("epoch", epoch)
            .with("inserts", report.inserts)
            .with("deletes", report.deletes)
            .with("removed", report.edges_removed)
            .with("touched", report.touched_shards.len())
            .with("edges", report.num_edges))
    }

    /// `watch` (register-or-advance) and `poll` (advance-only) for a
    /// standing query.  The first `watch` computes the fixpoint and emits
    /// every vertex; every later call emits only the changed lines
    /// (`<vertex> <bits>`).  Advancing may ingest window-expiry batches,
    /// so the whole call holds the dataset's ingest lock.
    fn cmd_watch(&self, req: &Request, register: bool) -> Result<Response> {
        use crate::engine::standing;
        let entry = self.engine_entry(req.req("data")?)?;
        let app = apps::by_name(req.req("app")?)?;
        if !register {
            anyhow::ensure!(
                entry.dir.watch_path(app.name()).exists(),
                "no standing query for {} on this dataset — send `watch` first",
                app.name()
            );
        }
        let window = match req.get("window") {
            Some(v) => Some(v.parse::<u32>().context("bad window")?),
            None => None,
        };
        let _ticket = self.sched.admit(JobClass::Heavy)?;
        let _guard = entry.ingest_lock.lock().unwrap();
        // pick up out-of-band CLI ingests before deciding what changed
        entry.engine.refresh_latest()?;
        let t0 = Instant::now();
        let out = standing::watch_advance(&entry.dir, &entry.engine, &app, window)?;
        Ok(Response::ok()
            .with("app", app.name())
            .with("epoch", out.epoch)
            .with("mode", out.mode.as_str())
            .with("registered", u8::from(out.registered))
            .with("expired", out.expired)
            .with("changed", out.lines.len())
            .with("wall_us", t0.elapsed().as_micros())
            .with_payload(out.lines))
    }

    // ---- the byte-stream side ------------------------------------------

    /// Serve one connection: request lines in, response blocks out, until
    /// EOF or shutdown.
    pub fn serve_stream<S: Read + Write>(&self, stream: S) {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let resp = self.handle(&line);
            let out = resp.render();
            let stream = reader.get_mut();
            if stream.write_all(out.as_bytes()).is_err() || stream.flush().is_err() {
                break;
            }
            if self.is_shutdown() {
                break;
            }
        }
    }

    /// Accept loop over localhost TCP.  Registers the listener so a
    /// `shutdown` request can poke the blocking accept.
    pub fn serve_tcp(self: &Arc<Self>, listener: TcpListener) -> Result<()> {
        let addr = listener.local_addr()?;
        self.wakers.lock().unwrap().push(WakeAddr::Tcp(addr));
        for conn in listener.incoming() {
            if self.is_shutdown() {
                break;
            }
            // accept-path sweep: a new connection reaps abandoned sessions
            // even if it never sends a request
            self.sessions.sweep_idle();
            if let Ok(stream) = conn {
                let srv = self.clone();
                std::thread::spawn(move || srv.serve_stream(stream));
            }
        }
        Ok(())
    }

    /// Accept loop over a Unix-domain socket (Unix only).
    #[cfg(unix)]
    pub fn serve_unix(
        self: &Arc<Self>,
        listener: std::os::unix::net::UnixListener,
        path: &Path,
    ) -> Result<()> {
        self.wakers.lock().unwrap().push(WakeAddr::Unix(path.to_path_buf()));
        for conn in listener.incoming() {
            if self.is_shutdown() {
                break;
            }
            self.sessions.sweep_idle();
            if let Ok(stream) = conn {
                let srv = self.clone();
                std::thread::spawn(move || srv.serve_stream(stream));
            }
        }
        let _ = std::fs::remove_file(path);
        Ok(())
    }

    /// Background idle sweeper: a timer tick that evicts TTL-expired
    /// sessions *and* idle engines even when the daemon receives no
    /// further requests or connections.  Exits once the shutdown flag is
    /// up (checked each tick, so it lingers at most one `interval`).
    pub fn spawn_sweeper(self: &Arc<Self>, interval: Duration) -> std::thread::JoinHandle<()> {
        let srv = self.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            if srv.is_shutdown() {
                break;
            }
            srv.sessions.sweep_idle();
            srv.sweep_idle_engines();
        })
    }

    /// Minimal plain-HTTP endpoint for `--metrics-listen`: any `GET` of
    /// `/metrics` (or `/`) answers the current exposition, so a stock
    /// Prometheus scraper attaches without speaking the line protocol.
    /// The accept loop polls the shutdown flag, so it needs no waker.
    pub fn serve_metrics_http(
        self: &Arc<Self>,
        listener: TcpListener,
    ) -> std::thread::JoinHandle<()> {
        listener.set_nonblocking(true).expect("metrics listener nonblocking");
        let srv = self.clone();
        std::thread::spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = srv.answer_http(stream);
                }
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
            if srv.is_shutdown() {
                break;
            }
        })
    }

    fn answer_http(&self, stream: std::net::TcpStream) -> std::io::Result<()> {
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(Duration::from_secs(5)))?;
        let mut reader = BufReader::new(stream);
        let mut request_line = String::new();
        reader.read_line(&mut request_line)?;
        // drain the headers up to the blank line (bounded)
        let mut line = String::new();
        for _ in 0..128 {
            line.clear();
            if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
                break;
            }
        }
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("");
        let path = parts.next().unwrap_or("");
        let stream = reader.get_mut();
        if method == "GET" && (path == "/metrics" || path == "/") {
            let body = self.metrics_text();
            write!(
                stream,
                "HTTP/1.1 200 OK\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                crate::obs::metrics::CONTENT_TYPE,
                body.len(),
                body
            )?;
        } else {
            let body = "not found\n";
            write!(
                stream,
                "HTTP/1.1 404 Not Found\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                body.len(),
            )?;
        }
        stream.flush()
    }

    /// Poke every registered listener so its accept loop observes the
    /// shutdown flag.
    fn wake_listeners(&self) {
        let wakers = self.wakers.lock().unwrap();
        for w in wakers.iter() {
            match w {
                WakeAddr::Tcp(addr) => {
                    let _ = std::net::TcpStream::connect(addr);
                }
                #[cfg(unix)]
                WakeAddr::Unix(path) => {
                    let _ = std::os::unix::net::UnixStream::connect(path);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sharding::{preprocess, PreprocessConfig};

    fn build_dataset(tag: &str) -> DatasetDir {
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_serve_{tag}_{}", std::process::id())),
        );
        let _ = std::fs::remove_dir_all(&dir.root);
        let edges = generator::erdos_renyi(128, 900, 77);
        let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
        preprocess(tag, &edges, 128, &dir, &cfg).unwrap();
        dir
    }

    fn server() -> Server {
        Server::new(
            EngineConfig { threads: 2, selective: false, ..Default::default() },
            SchedulerConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn rejects_epoch_pinned_config_and_unknown_commands() {
        let err = Server::new(
            EngineConfig { epoch: Some(0), ..Default::default() },
            SchedulerConfig::default(),
        );
        assert!(err.is_err());
        let srv = server();
        assert!(srv.handle("frobnicate x=1").error.is_some());
        assert!(srv.handle("open").error.is_some(), "missing data= must err, not panic");
        assert!(srv.handle("ping").is_ok());
    }

    #[test]
    fn sessions_stay_pinned_while_ingest_advances_the_epoch() {
        let dir = build_dataset("pin");
        let data = dir.root.display().to_string();
        let srv = server();

        let open1 = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open1.is_ok(), "{:?}", open1.error);
        assert_eq!(open1.get("epoch"), Some("0"));
        let s1 = open1.get("session").unwrap().to_string();

        let run = Request::new("run")
            .arg("session", &s1)
            .arg("app", "pagerank")
            .arg("values", "1")
            .render();
        let r1 = srv.handle(&run);
        assert!(r1.is_ok(), "{:?}", r1.error);
        assert_eq!(r1.payload.len(), 128);

        // mutate through the daemon: s1 must not move
        let batch = vec![
            mutation::Mutation::Insert { src: 0, dst: 100, weight: 1.0 },
            mutation::Mutation::Insert { src: 100, dst: 0, weight: 1.0 },
        ];
        let bpath = std::env::temp_dir().join(format!("gmp_serve_pin_{}.gmdl", std::process::id()));
        delta::save_log(&batch, &bpath).unwrap();
        let ing = srv.handle(
            &Request::new("ingest")
                .arg("data", &data)
                .arg("batch", &bpath.display().to_string())
                .render(),
        );
        assert!(ing.is_ok(), "{:?}", ing.error);
        assert_eq!(ing.get("epoch"), Some("1"));

        // the pinned session reproduces its pre-ingest payload exactly
        let r1b = srv.handle(&run);
        assert_eq!(r1b.payload, r1.payload, "pinned session drifted across an ingest");

        // a fresh session sees the new epoch and different values
        let open2 = srv.handle(&Request::new("open").arg("data", &data).render());
        assert_eq!(open2.get("epoch"), Some("1"));
        let s2 = open2.get("session").unwrap();
        let r2 = srv.handle(
            &Request::new("run")
                .arg("session", s2)
                .arg("app", "pagerank")
                .arg("values", "1")
                .render(),
        );
        assert!(r2.is_ok(), "{:?}", r2.error);
        assert_ne!(r2.payload, r1.payload, "new epoch must change pagerank");

        // value lookups are bit-exact echoes of the run payload
        let v = srv.handle(
            &Request::new("value")
                .arg("session", &s1)
                .arg("app", "pagerank")
                .arg("vertex", "5")
                .render(),
        );
        assert_eq!(v.get("value"), Some(r1.payload[5].as_str()));

        // degree reads come from the pinned snapshot
        let d = srv.handle(
            &Request::new("degree").arg("session", &s1).arg("vertex", "0").render(),
        );
        assert!(d.is_ok(), "{:?}", d.error);

        let c = srv.handle(&Request::new("close").arg("session", &s1).render());
        assert_eq!(c.get("closed"), Some("1"));
        assert!(srv
            .handle(&Request::new("value")
                .arg("session", &s1)
                .arg("app", "pagerank")
                .arg("vertex", "0")
                .render())
            .error
            .is_some());
        let _ = std::fs::remove_file(&bpath);
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn run_accepts_per_request_overrides_and_rejects_malformed() {
        let dir = build_dataset("ovr");
        let data = dir.root.display().to_string();
        let srv = server();
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        let sid = open.get("session").unwrap().to_string();

        let full = srv.handle(
            &Request::new("run")
                .arg("session", &sid)
                .arg("app", "pagerank")
                .arg("values", "1")
                .render(),
        );
        assert!(full.is_ok(), "{:?}", full.error);

        // iters=1 truncates the fixpoint for this request only
        let one = srv.handle(
            &Request::new("run")
                .arg("session", &sid)
                .arg("app", "pagerank")
                .arg("iters", "1")
                .arg("values", "1")
                .render(),
        );
        assert!(one.is_ok(), "{:?}", one.error);
        assert_eq!(one.get("iters"), Some("1"));
        assert_ne!(one.payload, full.payload, "iters=1 must truncate the fixpoint");

        // threads/codec overrides may not change a single bit
        let alt = srv.handle(
            &Request::new("run")
                .arg("session", &sid)
                .arg("app", "pagerank")
                .arg("threads", "1")
                .arg("codec", "none")
                .arg("values", "1")
                .render(),
        );
        assert!(alt.is_ok(), "{:?}", alt.error);
        assert_eq!(alt.payload, full.payload, "overrides must not change the fixpoint bits");

        // malformed overrides answer err and leave the session usable
        for (key, val) in
            [("iters", "many"), ("threads", "0"), ("threads", "-2"), ("codec", "brotli")]
        {
            let r = srv.handle(
                &Request::new("run")
                    .arg("session", &sid)
                    .arg("app", "pagerank")
                    .arg(key, val)
                    .render(),
            );
            assert!(r.error.is_some(), "{key}={val} must be rejected");
        }
        let again = srv.handle(
            &Request::new("run")
                .arg("session", &sid)
                .arg("app", "pagerank")
                .arg("values", "1")
                .render(),
        );
        assert_eq!(again.payload, full.payload, "a rejected override must not poison the engine");
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn idle_sessions_are_evicted_across_requests() {
        let dir = build_dataset("ttl");
        let data = dir.root.display().to_string();
        let srv = server().with_session_ttl(Some(Duration::from_millis(1)));
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        let sid = open.get("session").unwrap().to_string();
        std::thread::sleep(Duration::from_millis(20));
        // the sweep runs on dispatch, so any request flushes the idle one
        let stats = srv.handle("stats");
        assert_eq!(stats.get("sessions"), Some("0"), "idle session must be evicted");
        let gone = srv.handle(
            &Request::new("value")
                .arg("session", &sid)
                .arg("app", "pagerank")
                .arg("vertex", "0")
                .render(),
        );
        assert!(gone.error.is_some(), "evicted session must read as closed");
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn sweeper_thread_evicts_idle_sessions_without_any_request() {
        let dir = build_dataset("sweepthread");
        let data = dir.root.display().to_string();
        let srv = Arc::new(server().with_session_ttl(Some(Duration::from_millis(1))));
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        assert_eq!(srv.sessions.count(), 1);
        let sweeper = srv.spawn_sweeper(Duration::from_millis(2));
        // no further requests or connections: the timer tick alone reaps it
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.sessions.count() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(srv.sessions.count(), 0, "sweeper tick failed to evict the idle session");
        srv.shutdown.store(true, Ordering::SeqCst);
        sweeper.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn idle_engines_are_evicted_with_zero_further_requests() {
        let dir = build_dataset("engttl");
        let data = dir.root.display().to_string();
        let srv = Arc::new(server().with_engine_ttl(Some(Duration::from_millis(1))));
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        let sid = open.get("session").unwrap().to_string();
        assert_eq!(srv.engines.lock().unwrap().len(), 1);

        // a live session pins the dataset: the sweep may not evict
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(srv.sweep_idle_engines(), 0, "session still references the dataset");
        assert_eq!(srv.engines.lock().unwrap().len(), 1);

        let closed = srv.handle(&Request::new("close").arg("session", &sid).render());
        assert!(closed.is_ok(), "{:?}", closed.error);
        // zero further requests or connections: the timer tick alone
        // reaps the idle engine (mirror of the session-sweeper test)
        let sweeper = srv.spawn_sweeper(Duration::from_millis(2));
        let deadline = Instant::now() + Duration::from_secs(10);
        while !srv.engines.lock().unwrap().is_empty() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(srv.engines.lock().unwrap().is_empty(), "idle engine must be evicted");
        srv.shutdown.store(true, Ordering::SeqCst);
        sweeper.join().unwrap();

        // the dataset reopens transparently afterwards
        let re = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(re.is_ok(), "{:?}", re.error);
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn metrics_verb_exposes_parseable_prometheus_text() {
        use crate::obs::metrics as m;
        let dir = build_dataset("metrics");
        let data = dir.root.display().to_string();
        let srv = server();
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        let sid = open.get("session").unwrap().to_string();
        // another test in this binary may flip the global enabled flag for
        // an instant; retry the run+scrape instead of flaking on it
        let mut resp = Response::err("unscraped");
        for _ in 0..3 {
            m::set_enabled(true);
            let run = srv
                .handle(&Request::new("run").arg("session", &sid).arg("app", "pagerank").render());
            assert!(run.is_ok(), "{:?}", run.error);
            resp = srv.handle("metrics");
            let got_iters = resp
                .payload
                .iter()
                .filter_map(|l| m::parse_line(l))
                .any(|(n, _, v)| n == "graphmp_engine_iterations_total" && v > 0.0);
            if got_iters {
                break;
            }
        }
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.get("format"), Some("prometheus-0.0.4"));
        let text = resp.payload.join("\n");
        assert!(text.contains("# TYPE graphmp_sessions_open gauge"), "{text}");
        assert!(text.contains("# TYPE graphmp_engines_resident gauge"), "{text}");
        assert!(text.contains("# TYPE graphmp_engine_iterations_total counter"), "{text}");
        // every sample line must parse, and the engine must have reported
        for line in resp.payload.iter().filter(|l| !l.starts_with('#')) {
            assert!(m::parse_line(line).is_some(), "unparseable sample line: {line}");
        }
        let iters: f64 = resp
            .payload
            .iter()
            .filter_map(|l| m::parse_line(l))
            .filter(|(n, _, _)| n == "graphmp_engine_iterations_total")
            .map(|(_, _, v)| v)
            .sum();
        assert!(iters > 0.0, "a run must surface iterations in the exposition");
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn watch_then_poll_emits_exactly_the_dump_diff() {
        let dir = build_dataset("watch");
        let data = dir.root.display().to_string();
        let srv = server();

        // registration computes the fixpoint and emits every vertex
        let w0 =
            srv.handle(&Request::new("watch").arg("data", &data).arg("app", "spmv").render());
        assert!(w0.is_ok(), "{:?}", w0.error);
        assert_eq!(w0.get("registered"), Some("1"));
        assert_eq!(w0.payload.len(), 128);

        // quiet poll: nothing changed, nothing emitted
        let p0 = srv.handle(&Request::new("poll").arg("data", &data).arg("app", "spmv").render());
        assert!(p0.is_ok(), "{:?}", p0.error);
        assert_eq!(p0.get("mode"), Some("idle"));
        assert!(p0.payload.is_empty());

        // mutate through the daemon, then poll: delta-only re-emission
        let batch = vec![
            mutation::Mutation::Insert { src: 0, dst: 100, weight: 1.0 },
            mutation::Mutation::Insert { src: 100, dst: 0, weight: 1.0 },
        ];
        let bpath =
            std::env::temp_dir().join(format!("gmp_serve_watch_{}.gmdl", std::process::id()));
        delta::save_log(&batch, &bpath).unwrap();
        let ing = srv.handle(
            &Request::new("ingest")
                .arg("data", &data)
                .arg("batch", &bpath.display().to_string())
                .render(),
        );
        assert!(ing.is_ok(), "{:?}", ing.error);
        let p1 = srv.handle(&Request::new("poll").arg("data", &data).arg("app", "spmv").render());
        assert!(p1.is_ok(), "{:?}", p1.error);
        assert_eq!(p1.get("mode"), Some("rows"), "single-pass Sum must take the row path");

        // the changed-set must equal the diff of two full dumps
        let open = srv.handle(&Request::new("open").arg("data", &data).render());
        let run = srv.handle(
            &Request::new("run")
                .arg("session", open.get("session").unwrap())
                .arg("app", "spmv")
                .arg("values", "1")
                .render(),
        );
        assert!(run.is_ok(), "{:?}", run.error);
        let old: Vec<&str> =
            w0.payload.iter().map(|l| l.split_once(' ').unwrap().1).collect();
        let expect: Vec<String> = run
            .payload
            .iter()
            .enumerate()
            .filter(|(v, bits)| old[*v] != bits.as_str())
            .map(|(v, bits)| format!("{v} {bits}"))
            .collect();
        assert!(!expect.is_empty(), "the test batch must change some rows");
        assert_eq!(p1.payload, expect, "poll payload diverged from the dump diff");

        // poll without a prior watch is an error, not a registration
        let e = srv.handle(&Request::new("poll").arg("data", &data).arg("app", "sssp").render());
        assert!(e.error.is_some());
        let _ = std::fs::remove_file(&bpath);
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn tcp_round_trip_serves_and_shuts_down() {
        let dir = build_dataset("tcp");
        let data = dir.root.display().to_string();
        let srv = Arc::new(server());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv2 = srv.clone();
        let accept = std::thread::spawn(move || srv2.serve_tcp(listener).unwrap());

        let stream = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut send = |line: String| -> Response {
            let mut s = stream.try_clone().unwrap();
            s.write_all(line.as_bytes()).unwrap();
            s.flush().unwrap();
            Response::read_from(&mut reader).unwrap()
        };
        assert!(send(Request::new("ping").render()).is_ok());
        let open = send(Request::new("open").arg("data", &data).render());
        assert!(open.is_ok(), "{:?}", open.error);
        let run = send(
            Request::new("run")
                .arg("session", open.get("session").unwrap())
                .arg("app", "wcc")
                .arg("values", "1")
                .render(),
        );
        assert!(run.is_ok(), "{:?}", run.error);
        assert_eq!(run.payload.len(), 128);
        let bye = send(Request::new("shutdown").render());
        assert!(bye.is_ok());
        accept.join().unwrap();
        assert!(srv.is_shutdown());
        let _ = std::fs::remove_dir_all(&dir.root);
    }
}
