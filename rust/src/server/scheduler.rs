//! Admission control for the resident engine: per-class concurrency
//! limits plus a bounded waiting queue.
//!
//! Two job classes exist.  **Heavy** jobs (full program runs, ingests)
//! each occupy a worker-pool's worth of CPU, so only a couple may run at
//! once; **light** jobs (value/degree lookups, stats) are sub-millisecond
//! and get a generous limit of their own so a burst of heavy work can
//! never starve interactive queries.  A job past its class limit waits in
//! a shared bounded queue; once the queue is full further requests are
//! rejected immediately with `err busy` — backpressure the client can see
//! and retry, instead of an invisible pile-up inside the daemon.

use anyhow::{bail, Result};
use std::sync::{Condvar, Mutex};

/// Job classes, used to index the per-class tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobClass {
    /// Sub-millisecond lookups: `value`, `degree`, `info`, `stats`.
    Light = 0,
    /// Whole-engine work: `run`, `ingest`.
    Heavy = 1,
}

/// Knobs for [`Scheduler`]; the CLI exposes them as `--max-light`,
/// `--max-heavy` and `--max-queue`.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub max_light: usize,
    pub max_heavy: usize,
    /// Jobs (either class) allowed to wait for a slot before the daemon
    /// answers `err busy`.
    pub max_queue: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { max_light: 32, max_heavy: 2, max_queue: 16 }
    }
}

#[derive(Default)]
struct State {
    running: [usize; 2],
    queued: usize,
}

pub struct Scheduler {
    cfg: SchedulerConfig,
    state: Mutex<State>,
    cv: Condvar,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Self { cfg, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    fn limit(&self, class: JobClass) -> usize {
        match class {
            JobClass::Light => self.cfg.max_light.max(1),
            JobClass::Heavy => self.cfg.max_heavy.max(1),
        }
    }

    /// Admit a job of `class`: returns a ticket immediately when a slot is
    /// free, waits in the bounded queue otherwise, and fails fast with a
    /// `busy` error once the queue itself is full.  Dropping the ticket
    /// releases the slot.
    pub fn admit(&self, class: JobClass) -> Result<Ticket<'_>> {
        let limit = self.limit(class);
        let idx = class as usize;
        let mut s = self.state.lock().unwrap();
        if s.running[idx] >= limit {
            if s.queued >= self.cfg.max_queue {
                crate::obs::metrics::counter_add("graphmp_admission_busy_total", &[], 1);
                bail!(
                    "busy: {} {} job(s) running and {} queued",
                    s.running[idx],
                    if class == JobClass::Heavy { "heavy" } else { "light" },
                    s.queued
                );
            }
            s.queued += 1;
            while s.running[idx] >= limit {
                s = self.cv.wait(s).unwrap();
            }
            s.queued -= 1;
        }
        s.running[idx] += 1;
        Ok(Ticket { sched: self, class })
    }

    fn release(&self, class: JobClass) {
        let mut s = self.state.lock().unwrap();
        s.running[class as usize] -= 1;
        drop(s);
        self.cv.notify_all();
    }

    /// (running light, running heavy, queued) — the `stats` command's view.
    pub fn counts(&self) -> (usize, usize, usize) {
        let s = self.state.lock().unwrap();
        (s.running[0], s.running[1], s.queued)
    }
}

/// RAII admission slot; dropping it frees the slot and wakes a waiter.
pub struct Ticket<'a> {
    sched: &'a Scheduler,
    class: JobClass,
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        self.sched.release(self.class);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn per_class_limits_are_independent() {
        let s = Scheduler::new(SchedulerConfig { max_light: 4, max_heavy: 1, max_queue: 8 });
        let _h = s.admit(JobClass::Heavy).unwrap();
        // heavy is saturated, but light jobs still get slots immediately
        let l1 = s.admit(JobClass::Light).unwrap();
        let _l2 = s.admit(JobClass::Light).unwrap();
        assert_eq!(s.counts(), (2, 1, 0));
        drop(l1);
        assert_eq!(s.counts(), (1, 1, 0));
    }

    #[test]
    fn queue_overflow_rejects_with_busy() {
        let s = Scheduler::new(SchedulerConfig { max_light: 8, max_heavy: 1, max_queue: 0 });
        let _h = s.admit(JobClass::Heavy).unwrap();
        let err = s.admit(JobClass::Heavy).unwrap_err().to_string();
        assert!(err.contains("busy"), "{err}");
    }

    #[test]
    fn queued_jobs_run_when_a_slot_frees() {
        let s = Arc::new(Scheduler::new(SchedulerConfig {
            max_light: 8,
            max_heavy: 1,
            max_queue: 4,
        }));
        let done = Arc::new(AtomicUsize::new(0));
        let first = s.admit(JobClass::Heavy).unwrap();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let (s, done) = (s.clone(), done.clone());
            handles.push(std::thread::spawn(move || {
                let _t = s.admit(JobClass::Heavy).unwrap();
                done.fetch_add(1, Ordering::SeqCst);
            }));
        }
        // the three threads are parked in the queue, not running
        while s.counts().2 < 3 {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 0);
        drop(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(done.load(Ordering::SeqCst), 3);
        assert_eq!(s.counts(), (0, 0, 0));
    }
}
