//! Read sessions: each one pins an [`EpochState`] snapshot at `open` time
//! and keeps reading it — bit-identically — no matter how many ingests
//! advance the dataset underneath.  Closing the session (or the daemon
//! dropping it) releases the snapshot's Arc, letting the old epoch's
//! resident state go away once the last reader is done.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::EpochState;
use crate::graph::AnyValues;

/// One client session: an epoch-pinned view of one dataset.
pub struct Session {
    pub id: u64,
    pub dataset: PathBuf,
    /// The snapshot this session reads; never replaced for the session's
    /// lifetime (epoch pinning is structural, not advisory).
    pub state: Arc<EpochState>,
    /// Fixpoints computed by this session, keyed by app name, for `value`
    /// lookups without re-running.
    results: Mutex<HashMap<String, Arc<AnyValues>>>,
    /// Milliseconds (on the registry's clock) of the last `open`/`get`;
    /// the idle-eviction sweep compares against this.
    last_used_ms: AtomicU64,
}

impl Session {
    pub fn store_result(&self, app: &str, values: Arc<AnyValues>) {
        self.results.lock().unwrap().insert(app.to_string(), values);
    }

    pub fn result(&self, app: &str) -> Option<Arc<AnyValues>> {
        self.results.lock().unwrap().get(app).cloned()
    }
}

/// The daemon's session table.
///
/// A client that opens a session and silently goes away would otherwise
/// pin its epoch snapshot (and any stored fixpoints) forever; the
/// registry evicts sessions idle past `ttl` ([`Self::sweep_idle`], run by
/// the server on every dispatch).  Any `get` counts as use, so an active
/// session can never be evicted mid-conversation.
pub struct SessionRegistry {
    next_id: AtomicU64,
    map: Mutex<HashMap<u64, Arc<Session>>>,
    /// Clock origin for `last_used_ms` stamps.
    t0: Instant,
    /// `None` = idle eviction disabled.
    ttl: Option<Duration>,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        Self::with_ttl(None)
    }
}

impl SessionRegistry {
    pub fn with_ttl(ttl: Option<Duration>) -> Self {
        Self {
            next_id: AtomicU64::new(0),
            map: Mutex::new(HashMap::new()),
            t0: Instant::now(),
            ttl,
        }
    }

    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    pub fn open(&self, dataset: PathBuf, state: Arc<EpochState>) -> Arc<Session> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let session = Arc::new(Session {
            id,
            dataset,
            state,
            results: Mutex::new(HashMap::new()),
            last_used_ms: AtomicU64::new(self.now_ms()),
        });
        self.map.lock().unwrap().insert(id, session.clone());
        session
    }

    pub fn get(&self, id: u64) -> Result<Arc<Session>> {
        let s = self
            .map
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("no such session {id} (closed?)"))?;
        s.last_used_ms.store(self.now_ms(), Ordering::Relaxed);
        Ok(s)
    }

    /// Returns whether the session existed.
    pub fn close(&self, id: u64) -> bool {
        self.map.lock().unwrap().remove(&id).is_some()
    }

    pub fn count(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Does any live session pin `dataset`?  Idle-*engine* eviction must
    /// keep an engine whose snapshots are still reachable this way.
    pub fn references(&self, dataset: &std::path::Path) -> bool {
        self.map.lock().unwrap().values().any(|s| s.dataset == dataset)
    }

    /// Evict sessions idle past the registry's TTL; returns how many went.
    /// No-op when no TTL is configured.
    pub fn sweep_idle(&self) -> usize {
        match self.ttl {
            Some(ttl) => self.sweep_idle_at(self.now_ms(), ttl),
            None => 0,
        }
    }

    /// The sweep against an explicit clock reading — split out so tests
    /// can drive time instead of sleeping.
    pub fn sweep_idle_at(&self, now_ms: u64, ttl: Duration) -> usize {
        let ttl_ms = ttl.as_millis() as u64;
        let mut map = self.map.lock().unwrap();
        let before = map.len();
        map.retain(|_, s| {
            now_ms.saturating_sub(s.last_used_ms.load(Ordering::Relaxed)) <= ttl_ms
        });
        before - map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_state() -> Arc<EpochState> {
        Arc::new(EpochState {
            epoch: 3,
            property: crate::storage::property::Property {
                name: "t".into(),
                info: crate::graph::GraphInfo {
                    num_vertices: 4,
                    num_edges: 0,
                    max_in_degree: 0,
                    max_out_degree: 0,
                },
                intervals: vec![0, 4],
            },
            vertex_info: crate::storage::vertexinfo::VertexInfo::new(crate::graph::Degrees {
                in_deg: vec![0; 4],
                out_deg: vec![0; 4],
            }),
            blooms: Vec::new(),
            shard_paths: Vec::new(),
            shard_epochs: Vec::new(),
            deltas: Vec::new(),
        })
    }

    #[test]
    fn sessions_open_pin_and_close() {
        let reg = SessionRegistry::default();
        let st = dummy_state();
        let s1 = reg.open(PathBuf::from("/a"), st.clone());
        let s2 = reg.open(PathBuf::from("/a"), st);
        assert_ne!(s1.id, s2.id);
        assert_eq!(reg.count(), 2);
        assert_eq!(reg.get(s1.id).unwrap().state.epoch, 3);
        assert!(reg.close(s1.id));
        assert!(!reg.close(s1.id), "double close must report absence");
        assert!(reg.get(s1.id).is_err());
        assert_eq!(reg.count(), 1);
    }

    #[test]
    fn idle_sessions_are_swept_but_touched_ones_survive() {
        let reg = SessionRegistry::with_ttl(Some(std::time::Duration::from_secs(10)));
        let st = dummy_state();
        let idle = reg.open(PathBuf::from("/a"), st.clone());
        let busy = reg.open(PathBuf::from("/a"), st);
        // pretend both were opened at t=0 on the registry clock
        idle.last_used_ms.store(0, Ordering::Relaxed);
        busy.last_used_ms.store(0, Ordering::Relaxed);
        // within the TTL nothing goes
        assert_eq!(reg.sweep_idle_at(10_000, std::time::Duration::from_secs(10)), 0);
        assert_eq!(reg.count(), 2);
        // `get` counts as use, so only the untouched session is evicted
        busy.last_used_ms.store(11_000, Ordering::Relaxed);
        assert_eq!(reg.sweep_idle_at(12_000, std::time::Duration::from_secs(10)), 1);
        assert_eq!(reg.count(), 1);
        assert!(reg.get(idle.id).is_err(), "idle session must be gone");
        assert!(reg.get(busy.id).is_ok(), "recently used session must survive");
        // a disabled-TTL registry never sweeps
        let off = SessionRegistry::default();
        let s = off.open(PathBuf::from("/a"), dummy_state());
        s.last_used_ms.store(0, Ordering::Relaxed);
        assert_eq!(off.sweep_idle(), 0);
        assert_eq!(off.count(), 1);
    }

    #[test]
    fn get_refreshes_last_used() {
        let reg = SessionRegistry::with_ttl(Some(std::time::Duration::from_millis(50)));
        let s = reg.open(PathBuf::from("/a"), dummy_state());
        s.last_used_ms.store(0, Ordering::Relaxed);
        let _ = reg.get(s.id).unwrap(); // re-stamps to "now"
        let stamped = s.last_used_ms.load(Ordering::Relaxed);
        assert!(stamped <= reg.now_ms());
        assert_eq!(reg.sweep_idle_at(stamped, std::time::Duration::from_millis(50)), 0);
    }

    #[test]
    fn results_are_stored_per_app() {
        let reg = SessionRegistry::default();
        let s = reg.open(PathBuf::from("/a"), dummy_state());
        assert!(s.result("pagerank").is_none());
        s.store_result("pagerank", Arc::new(AnyValues::U32(vec![1, 2, 3])));
        let v = s.result("pagerank").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v.render_bits(1).as_deref(), Some("2"));
    }
}
