//! Read sessions: each one pins an [`EpochState`] snapshot at `open` time
//! and keeps reading it — bit-identically — no matter how many ingests
//! advance the dataset underneath.  Closing the session (or the daemon
//! dropping it) releases the snapshot's Arc, letting the old epoch's
//! resident state go away once the last reader is done.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::engine::EpochState;
use crate::graph::AnyValues;

/// One client session: an epoch-pinned view of one dataset.
pub struct Session {
    pub id: u64,
    pub dataset: PathBuf,
    /// The snapshot this session reads; never replaced for the session's
    /// lifetime (epoch pinning is structural, not advisory).
    pub state: Arc<EpochState>,
    /// Fixpoints computed by this session, keyed by app name, for `value`
    /// lookups without re-running.
    results: Mutex<HashMap<String, Arc<AnyValues>>>,
}

impl Session {
    pub fn store_result(&self, app: &str, values: Arc<AnyValues>) {
        self.results.lock().unwrap().insert(app.to_string(), values);
    }

    pub fn result(&self, app: &str) -> Option<Arc<AnyValues>> {
        self.results.lock().unwrap().get(app).cloned()
    }
}

/// The daemon's session table.
#[derive(Default)]
pub struct SessionRegistry {
    next_id: AtomicU64,
    map: Mutex<HashMap<u64, Arc<Session>>>,
}

impl SessionRegistry {
    pub fn open(&self, dataset: PathBuf, state: Arc<EpochState>) -> Arc<Session> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let session =
            Arc::new(Session { id, dataset, state, results: Mutex::new(HashMap::new()) });
        self.map.lock().unwrap().insert(id, session.clone());
        session
    }

    pub fn get(&self, id: u64) -> Result<Arc<Session>> {
        self.map
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("no such session {id} (closed?)"))
    }

    /// Returns whether the session existed.
    pub fn close(&self, id: u64) -> bool {
        self.map.lock().unwrap().remove(&id).is_some()
    }

    pub fn count(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_state() -> Arc<EpochState> {
        Arc::new(EpochState {
            epoch: 3,
            property: crate::storage::property::Property {
                name: "t".into(),
                info: crate::graph::GraphInfo {
                    num_vertices: 4,
                    num_edges: 0,
                    max_in_degree: 0,
                    max_out_degree: 0,
                },
                intervals: vec![0, 4],
            },
            vertex_info: crate::storage::vertexinfo::VertexInfo::new(crate::graph::Degrees {
                in_deg: vec![0; 4],
                out_deg: vec![0; 4],
            }),
            blooms: Vec::new(),
            shard_paths: Vec::new(),
            shard_epochs: Vec::new(),
            deltas: Vec::new(),
        })
    }

    #[test]
    fn sessions_open_pin_and_close() {
        let reg = SessionRegistry::default();
        let st = dummy_state();
        let s1 = reg.open(PathBuf::from("/a"), st.clone());
        let s2 = reg.open(PathBuf::from("/a"), st);
        assert_ne!(s1.id, s2.id);
        assert_eq!(reg.count(), 2);
        assert_eq!(reg.get(s1.id).unwrap().state.epoch, 3);
        assert!(reg.close(s1.id));
        assert!(!reg.close(s1.id), "double close must report absence");
        assert!(reg.get(s1.id).is_err());
        assert_eq!(reg.count(), 1);
    }

    #[test]
    fn results_are_stored_per_app() {
        let reg = SessionRegistry::default();
        let s = reg.open(PathBuf::from("/a"), dummy_state());
        assert!(s.result("pagerank").is_none());
        s.store_result("pagerank", Arc::new(AnyValues::U32(vec![1, 2, 3])));
        let v = s.result("pagerank").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v.render_bits(1).as_deref(), Some("2"));
    }
}
