//! The serve line protocol: one request per line, one response per
//! request, plain UTF-8 over any byte stream (localhost TCP or a Unix
//! socket).  No framing library, no serialization dependency — the whole
//! wire format is:
//!
//! ```text
//! request  := cmd [SP key=value]* LF
//!             payload-line{N} LF               -- iff the header carries lines=N
//! response := ("ok" [SP key=value]*) | ("err" SP message) LF
//!             payload-line{N} LF               -- iff the header carries lines=N
//! ```
//!
//! Keys are bare identifiers; values and error messages are
//! percent-escaped so embedded spaces, `%`, `=` and control characters
//! survive the line discipline.  Payload lines are raw (the bit-exact
//! value rendering never contains specials), which keeps a `run values=1`
//! payload byte-for-byte identical to a `--dump-values` file.
//!
//! Requests carry payloads symmetrically to responses (the partition
//! barrier ships delta lines *to* workers): [`Request::with_payload`]
//! appends `lines=N` to the rendered header and the receiving side reads
//! them back through [`Request::read_from`].  The serve daemon's
//! line-at-a-time `handle` path never uses request payloads.

use anyhow::{bail, Context, Result};
use std::io::BufRead;

/// Percent-escape everything a `key=value` token can't carry verbatim.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            ' ' | '%' | '=' | '\x00'..='\x1f' | '\x7f' => {
                out.push('%');
                out.push_str(&format!("{:02x}", c as u32));
            }
            _ => out.push(c),
        }
    }
    out
}

/// Invert [`escape`].  Rejects truncated or non-hex escapes.
pub fn unescape(s: &str) -> Result<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            anyhow::ensure!(i + 2 < bytes.len(), "truncated escape in {s:?}");
            let hex = std::str::from_utf8(&bytes[i + 1..i + 3])?;
            out.push(u8::from_str_radix(hex, 16).with_context(|| format!("bad escape %{hex}"))?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).context("unescaped request is not UTF-8")
}

/// A parsed request line, plus optional payload lines (declared via a
/// `lines=N` key, mirroring [`Response`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub cmd: String,
    pub kv: Vec<(String, String)>,
    pub payload: Vec<String>,
}

impl Request {
    pub fn new(cmd: &str) -> Self {
        Self { cmd: cmd.to_string(), kv: Vec::new(), payload: Vec::new() }
    }

    pub fn arg(mut self, key: &str, value: &str) -> Self {
        self.kv.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_payload(mut self, lines: Vec<String>) -> Self {
        self.payload = lines;
        self
    }

    /// Parse a bare header line.  A `lines=N` key stays in `kv`; the
    /// payload itself is consumed by [`Self::read_from`].
    pub fn parse(line: &str) -> Result<Request> {
        let line = line.trim_end_matches(['\r', '\n']);
        let mut tokens = line.split(' ').filter(|t| !t.is_empty());
        let cmd = tokens.next().context("empty request")?.to_string();
        let mut kv = Vec::new();
        for t in tokens {
            let (k, v) = t.split_once('=').with_context(|| format!("bad token {t:?}"))?;
            kv.push((k.to_string(), unescape(v)?));
        }
        Ok(Request { cmd, kv, payload: Vec::new() })
    }

    /// Server side: read one request (header + declared payload lines)
    /// off a buffered stream.  `Ok(None)` = clean EOF before a header.
    pub fn read_from(reader: &mut impl BufRead) -> Result<Option<Request>> {
        let mut header = String::new();
        loop {
            header.clear();
            if reader.read_line(&mut header)? == 0 {
                return Ok(None);
            }
            if !header.trim().is_empty() {
                break;
            }
        }
        let mut req = Request::parse(&header)?;
        let n = req.get_u64("lines")?.unwrap_or(0) as usize;
        req.payload.reserve(n);
        for _ in 0..n {
            let mut line = String::new();
            anyhow::ensure!(reader.read_line(&mut line)? > 0, "request payload truncated");
            while line.ends_with('\n') || line.ends_with('\r') {
                line.pop();
            }
            req.payload.push(line);
        }
        Ok(Some(req))
    }

    /// Wire form, `lines=N` appended automatically when a payload rides
    /// along (so `kv` must not carry its own `lines` key).
    pub fn render(&self) -> String {
        let mut s = self.cmd.clone();
        for (k, v) in &self.kv {
            s.push(' ');
            s.push_str(k);
            s.push('=');
            s.push_str(&escape(v));
        }
        if !self.payload.is_empty() {
            s.push_str(&format!(" lines={}", self.payload.len()));
        }
        s.push('\n');
        for line in &self.payload {
            s.push_str(line);
            s.push('\n');
        }
        s
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("{}: missing {key}=", self.cmd))
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("{}: bad {key}={v:?}", self.cmd)))
            .transpose()
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get_u64(key)?.with_context(|| format!("{}: missing {key}=", self.cmd))
    }
}

/// A response: header keys plus optional payload lines.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// `None` = ok; `Some(msg)` = error.
    pub error: Option<String>,
    pub kv: Vec<(String, String)>,
    pub payload: Vec<String>,
}

impl Response {
    pub fn ok() -> Self {
        Self { error: None, kv: Vec::new(), payload: Vec::new() }
    }

    pub fn err(msg: impl std::fmt::Display) -> Self {
        Self { error: Some(msg.to_string()), kv: Vec::new(), payload: Vec::new() }
    }

    pub fn with(mut self, key: &str, value: impl std::fmt::Display) -> Self {
        self.kv.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_payload(mut self, lines: Vec<String>) -> Self {
        self.payload = lines;
        self
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Wire form, `lines=N` appended automatically when a payload rides
    /// along.
    pub fn render(&self) -> String {
        let mut s = String::new();
        match &self.error {
            Some(msg) => {
                s.push_str("err ");
                s.push_str(&escape(msg));
            }
            None => {
                s.push_str("ok");
                for (k, v) in &self.kv {
                    s.push(' ');
                    s.push_str(k);
                    s.push('=');
                    s.push_str(&escape(v));
                }
                if !self.payload.is_empty() {
                    s.push_str(&format!(" lines={}", self.payload.len()));
                }
            }
        }
        s.push('\n');
        for line in &self.payload {
            s.push_str(line);
            s.push('\n');
        }
        s
    }

    /// Client side: read one response (header + declared payload lines)
    /// off a buffered stream.
    pub fn read_from(reader: &mut impl BufRead) -> Result<Response> {
        let mut header = String::new();
        anyhow::ensure!(reader.read_line(&mut header)? > 0, "connection closed");
        let header = header.trim_end_matches(['\r', '\n']);
        if let Some(msg) = header.strip_prefix("err ") {
            return Ok(Response::err(unescape(msg)?));
        }
        let rest = match header {
            "ok" => "",
            _ => header.strip_prefix("ok ").with_context(|| format!("bad response {header:?}"))?,
        };
        let mut kv = Vec::new();
        for t in rest.split(' ').filter(|t| !t.is_empty()) {
            let (k, v) = t.split_once('=').with_context(|| format!("bad token {t:?}"))?;
            kv.push((k.to_string(), unescape(v)?));
        }
        let n: usize = match kv.iter().find(|(k, _)| k == "lines") {
            Some((_, v)) => v.parse().context("bad lines= count")?,
            None => 0,
        };
        let mut payload = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            anyhow::ensure!(reader.read_line(&mut line)? > 0, "payload truncated");
            while line.ends_with('\n') || line.ends_with('\r') {
                line.pop();
            }
            payload.push(line);
        }
        Ok(Response { error: None, kv, payload })
    }
}

/// Parse a request line, surfacing malformed input as an `err` response
/// instead of tearing the connection down.
pub fn handle_malformed(line: &str) -> std::result::Result<Request, Response> {
    Request::parse(line).map_err(|e| Response::err(format!("{e:#}")))
}

/// Verbs of the partition protocol (`graphmp partrun`): the coordinator
/// drives each worker process over this same line protocol on a private
/// Unix socket.  One request/response pair per worker per barrier:
///
/// ```text
/// part-init app=<name> shards=<lo:hi[,lo:hi]*>
///   -> ok epoch=E vertices=N lane=L active=A         (A = global initial frontier)
/// part-step iter=K active=A [lines=M + M delta lines from *other* workers]
///   -> ok active=a processed=p skipped=s [lines=m + m own delta lines]
/// part-values
///   -> ok lines=R + bit-exact value lines of the owned intervals, ascending
/// part-shutdown
///   -> ok                                            (worker exits afterwards)
/// ```
///
/// Delta lines are [`crate::engine::partition::encode_delta`]'s
/// `"{v} {bits} {flag}"` form: the bit-changed values of the sender's
/// ranges, with `flag = 1` marking tolerance-active vertices (the
/// frontier bits).  `active=` on `part-step` is the *merged* global count
/// — each worker derives the same selective-scheduling decision from it
/// that the single-process engine would.
pub mod part {
    /// Bind a program + owned shard ranges; compute the init state.
    pub const INIT: &str = "part-init";
    /// Run one iteration barrier-to-barrier.
    pub const STEP: &str = "part-step";
    /// Dump the owned intervals' final values.
    pub const VALUES: &str = "part-values";
    /// Clean worker exit.
    pub const SHUTDOWN: &str = "part-shutdown";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_roundtrips_specials() {
        for s in ["plain", "with space", "a=b%c", "tab\there", "nl\nthere", ""] {
            assert_eq!(unescape(&escape(s)).unwrap(), s, "roundtrip {s:?}");
        }
        assert!(unescape("%zz").is_err());
        assert!(unescape("%1").is_err());
    }

    #[test]
    fn request_roundtrips_through_wire_form() {
        let r = Request::new("open").arg("data", "/tmp/my data").arg("epoch", "3");
        let back = Request::parse(&r.render()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.get("data"), Some("/tmp/my data"));
        assert_eq!(back.req_u64("epoch").unwrap(), 3);
        assert!(back.req("missing").is_err());
    }

    #[test]
    fn request_payload_roundtrips_through_read_from() {
        let r = Request::new(part::STEP)
            .arg("iter", "3")
            .arg("active", "17")
            .with_payload(vec!["5 3f800000 1".into(), "9 40000000 0".into()]);
        let wire = r.render();
        assert!(wire.starts_with("part-step iter=3 active=17 lines=2\n"), "{wire:?}");
        let mut reader = std::io::BufReader::new(wire.as_bytes());
        let back = Request::read_from(&mut reader).unwrap().unwrap();
        assert_eq!(back.cmd, part::STEP);
        assert_eq!(back.req_u64("iter").unwrap(), 3);
        assert_eq!(back.payload, r.payload);
        // stream exhausted -> clean EOF
        assert!(Request::read_from(&mut reader).unwrap().is_none());
        // declared payload that never arrives is an error, not a hang
        let mut truncated =
            std::io::BufReader::new("part-step iter=0 lines=2\nonly one\n".as_bytes());
        assert!(Request::read_from(&mut truncated).is_err());
    }

    #[test]
    fn response_roundtrips_with_payload() {
        let resp = Response::ok()
            .with("epoch", 2)
            .with("app", "pagerank")
            .with_payload(vec!["3f800000".into(), "00000000".into()]);
        let wire = resp.render();
        assert!(wire.starts_with("ok epoch=2 app=pagerank lines=2\n"), "{wire:?}");
        let mut r = std::io::BufReader::new(wire.as_bytes());
        let back = Response::read_from(&mut r).unwrap();
        assert!(back.is_ok());
        assert_eq!(back.get("epoch"), Some("2"));
        assert_eq!(back.payload, vec!["3f800000", "00000000"]);
    }

    #[test]
    fn error_responses_carry_escaped_messages() {
        let resp = Response::err("no such session 7 (closed?)");
        let mut r = std::io::BufReader::new(resp.render().as_bytes());
        let back = Response::read_from(&mut r).unwrap();
        assert_eq!(back.error.as_deref(), Some("no such session 7 (closed?)"));
    }
}
