//! External-memory preprocessing — the paper's step 3 verbatim: "read the
//! graph data sequentially, and append each edge to a shard file based on
//! its destination and vertex intervals".
//!
//! Unlike [`super::preprocess`] (which buckets in memory and is fine for
//! the scaled datasets), this path holds only O(|V|) degree state plus
//! bounded per-shard append buffers, so graphs far larger than RAM
//! preprocess in two sequential passes over the input file:
//!
//! * pass 1 — stream edges, count degrees (step 1);
//! * compute intervals (step 2);
//! * pass 2 — stream edges again, append each to its shard's spill file
//!   through buffered, I/O-accounted appends (step 3);
//! * per shard: read spill file, CSR-transform, persist shard + Bloom
//!   filter, delete spill (step 4).

use std::path::Path;

use anyhow::{Context, Result};

use crate::bloom::BloomFilter;
use crate::graph::csr::Csr;
use crate::graph::edgelist::BinaryEdgeStream;
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::storage::format::frame;
use crate::storage::property::Property;
use crate::storage::vertexinfo::VertexInfo;
use crate::storage::{io, DatasetDir};

use super::preprocess::{PreprocessConfig, PreprocessOutput};

/// Per-shard append buffer size in edges (8 B each). 4096 edges = 32 KiB —
/// large enough to amortize appends, small enough that P buffers stay
/// bounded (P=1000 shards ⇒ 32 MiB).
const SPILL_BUFFER_EDGES: usize = 4096;

/// Streaming counterpart of [`super::preprocess`]: input is a binary edge
/// list *file* (written by `edgelist::write_binary` /
/// `edgelist::write_binary_weighted` / `graphmp generate`).  A v2
/// (weighted) input streams its weight lane through the spill files into
/// the shard CSRs.
pub fn preprocess_streaming(
    name: &str,
    input: &Path,
    num_vertices: usize,
    out: &DatasetDir,
    cfg: &PreprocessConfig,
) -> Result<PreprocessOutput> {
    out.create()?;
    let v_cap = crate::runtime::geometry::V_MAX;

    // -- pass 1: scan (degrees + bounds check) ---------------------------
    let mut degrees = Degrees {
        in_deg: vec![0; num_vertices],
        out_deg: vec![0; num_vertices],
    };
    let mut num_edges = 0u64;
    let scan = BinaryEdgeStream::open(input)?;
    let weighted = scan.weighted();
    for e in scan {
        let ((s, d), _w) = e?;
        anyhow::ensure!(
            (s as usize) < num_vertices && (d as usize) < num_vertices,
            "edge ({s},{d}) outside vertex range {num_vertices}"
        );
        degrees.out_deg[s as usize] += 1;
        degrees.in_deg[d as usize] += 1;
        num_edges += 1;
    }
    let info = degrees.info(num_edges);

    // -- step 2: intervals -------------------------------------------------
    let mut intervals =
        super::intervals::compute_intervals(&degrees.in_deg, cfg.max_edges_per_shard);
    intervals = super::preprocess::split_wide_intervals(&intervals, v_cap);
    let p = intervals.len() - 1;

    // -- pass 2 / step 3: append each edge to its shard spill file ---------
    // spill records are 8 B (s,d) unweighted or 12 B (s,d,w) weighted
    let rec = if weighted { 12 } else { 8 };
    let spill_path = |i: usize| out.root.join(format!("spill_{i:04}.tmp"));
    let mut buffers: Vec<Vec<u8>> = vec![Vec::with_capacity(SPILL_BUFFER_EDGES * rec); p];
    // spill files must start empty even if a previous run crashed mid-way
    for i in 0..p {
        let _ = std::fs::remove_file(spill_path(i));
    }
    let shard_of = |v: VertexId| -> usize {
        match intervals.binary_search(&v) {
            Ok(i) => i.min(p - 1),
            Err(i) => i - 1,
        }
    };
    let flush = |i: usize, buf: &mut Vec<u8>| -> Result<()> {
        if !buf.is_empty() {
            io::append_file(&spill_path(i), buf)?;
            buf.clear();
        }
        Ok(())
    };
    for e in BinaryEdgeStream::open(input)? {
        let ((s, d), w) = e?;
        let i = shard_of(d);
        buffers[i].extend_from_slice(&s.to_le_bytes());
        buffers[i].extend_from_slice(&d.to_le_bytes());
        if weighted {
            buffers[i].extend_from_slice(&w.to_le_bytes());
        }
        if buffers[i].len() >= SPILL_BUFFER_EDGES * rec {
            flush(i, &mut buffers[i])?;
        }
    }
    for (i, buf) in buffers.iter_mut().enumerate() {
        flush(i, buf)?;
    }
    drop(buffers);

    // -- step 4: CSR transform + persist shard by shard --------------------
    let mut shard_edge_counts = Vec::with_capacity(p);
    let mut bloom_bytes = 0u64;
    for i in 0..p {
        let (lo, hi) = (intervals[i], intervals[i + 1]);
        let mut bucket: Vec<Edge> = Vec::new();
        let mut wbucket: Vec<Weight> = Vec::new();
        if std::fs::metadata(spill_path(i)).is_ok() {
            let bytes = io::read_file(&spill_path(i))?;
            anyhow::ensure!(bytes.len() % rec == 0, "spill {i} misaligned");
            for c in bytes.chunks_exact(rec) {
                bucket.push((
                    u32::from_le_bytes(c[0..4].try_into().unwrap()),
                    u32::from_le_bytes(c[4..8].try_into().unwrap()),
                ));
                if weighted {
                    wbucket.push(f32::from_le_bytes(c[8..12].try_into().unwrap()));
                }
            }
        }
        let csr = Csr::from_edges_weighted(lo, hi, &bucket, &wbucket);
        csr.validate().with_context(|| format!("shard {i}"))?;
        crate::storage::shardfile::save(&csr, &out.shard_path(i))?;
        shard_edge_counts.push(csr.num_edges() as u64);

        let mut bloom = BloomFilter::with_capacity(bucket.len().max(1), cfg.bloom_fpr);
        for &(s, _) in &bucket {
            bloom.insert(s as u64);
        }
        let framed = frame(
            super::preprocess::BLOOM_MAGIC,
            super::preprocess::BLOOM_VERSION,
            &bloom.to_bytes(),
        );
        bloom_bytes += framed.len() as u64;
        io::write_file(&out.bloom_path(i), &framed)?;
        let _ = std::fs::remove_file(spill_path(i));
    }

    let property = Property { name: name.to_string(), info, intervals };
    property.save(&out.property_path())?;
    VertexInfo::new(degrees).save(&out.vertexinfo_path())?;
    Ok(PreprocessOutput { property, shard_edge_counts, bloom_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{edgelist, generator};
    use crate::storage::shardfile;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gmp_stream_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn streaming_equals_in_memory_pipeline() {
        let base = tmp("eq");
        let edges = generator::rmat(10, 8000, generator::RmatParams::default(), 21);
        let input = base.join("edges.bin");
        edgelist::write_binary(&input, &edges).unwrap();
        let cfg = PreprocessConfig { max_edges_per_shard: 1024, bloom_fpr: 0.01 };

        let mem_dir = DatasetDir::new(base.join("mem.gmp"));
        let mem = super::super::preprocess("g", &edges, 1 << 10, &mem_dir, &cfg).unwrap();

        let st_dir = DatasetDir::new(base.join("stream.gmp"));
        let st = preprocess_streaming("g", &input, 1 << 10, &st_dir, &cfg).unwrap();

        // identical metadata
        assert_eq!(mem.property.intervals, st.property.intervals);
        assert_eq!(mem.property.info, st.property.info);
        assert_eq!(mem.shard_edge_counts, st.shard_edge_counts);
        // identical shard contents (edge multisets per shard)
        for i in 0..mem.property.num_shards() {
            let a = shardfile::load(&mem_dir.shard_path(i)).unwrap();
            let b = shardfile::load(&st_dir.shard_path(i)).unwrap();
            let mut ea = a.to_edges();
            let mut eb = b.to_edges();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "shard {i}");
        }
        // no spill files left behind
        assert!(!std::fs::read_dir(&st_dir.root)
            .unwrap()
            .any(|e| e.unwrap().file_name().to_string_lossy().ends_with(".tmp")));
    }

    #[test]
    fn streamed_dataset_runs_in_engine() {
        use crate::apps::PageRank;
        use crate::engine::{EngineConfig, VswEngine};
        let base = tmp("run");
        let edges = generator::erdos_renyi(300, 3000, 8);
        let input = base.join("e.bin");
        edgelist::write_binary(&input, &edges).unwrap();
        let dir = DatasetDir::new(base.join("d.gmp"));
        preprocess_streaming("r", &input, 300, &dir, &PreprocessConfig::default()).unwrap();
        let engine =
            VswEngine::open(dir, EngineConfig { max_iters: 3, ..Default::default() }).unwrap();
        let run = engine.run(&PageRank::default()).unwrap();
        assert_eq!(run.values.len(), 300);
        assert!(run.values.iter().all(|v| *v > 0.0));
    }

    #[test]
    fn rejects_out_of_range() {
        let base = tmp("oob");
        let input = base.join("e.bin");
        edgelist::write_binary(&input, &[(0, 99)]).unwrap();
        let dir = DatasetDir::new(base.join("d.gmp"));
        assert!(preprocess_streaming("x", &input, 10, &dir, &PreprocessConfig::default()).is_err());
    }

    #[test]
    fn weighted_streaming_equals_weighted_in_memory_pipeline() {
        let base = tmp("weq");
        let edges = generator::rmat(9, 3000, generator::RmatParams::default(), 5);
        let weights = generator::synth_weights(&edges, 99);
        let input = base.join("edges.bin");
        edgelist::write_binary_weighted(&input, &edges, &weights).unwrap();
        let cfg = PreprocessConfig { max_edges_per_shard: 512, bloom_fpr: 0.01 };

        let mem_dir = DatasetDir::new(base.join("mem.gmp"));
        let mem = super::super::preprocess::preprocess_weighted(
            "g", &edges, &weights, 1 << 9, &mem_dir, &cfg,
        )
        .unwrap();

        let st_dir = DatasetDir::new(base.join("stream.gmp"));
        let st = preprocess_streaming("g", &input, 1 << 9, &st_dir, &cfg).unwrap();

        assert_eq!(mem.property.intervals, st.property.intervals);
        assert_eq!(mem.shard_edge_counts, st.shard_edge_counts);
        for i in 0..mem.property.num_shards() {
            let a = shardfile::load(&mem_dir.shard_path(i)).unwrap();
            let b = shardfile::load(&st_dir.shard_path(i)).unwrap();
            assert_eq!(a.is_weighted(), b.is_weighted(), "shard {i}");
            let mut ea = a.to_wedges();
            let mut eb = b.to_wedges();
            ea.sort_by(|x, y| x.partial_cmp(y).unwrap());
            eb.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(ea, eb, "shard {i}");
        }
    }
}
