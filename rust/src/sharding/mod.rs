//! Graph sharding: vertex-interval computation and the 4-step preprocessing
//! pipeline (paper §II-B).
//!
//! 1. scan the graph, record in/out degrees;
//! 2. compute vertex intervals so every shard fits memory and edge counts
//!    are balanced;
//! 3. append each edge to its shard by destination;
//! 4. transform shards to CSR, persist metadata (+ the Bloom filters used
//!    by selective scheduling, built here so the engine never rescans).

pub mod intervals;
pub mod preprocess;
pub mod streaming;

pub use intervals::compute_intervals;
pub use preprocess::{preprocess, preprocess_weighted, PreprocessConfig, PreprocessOutput};
pub use streaming::preprocess_streaming;
