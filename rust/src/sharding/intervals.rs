//! Vertex-interval computation (§II-B policies):
//!
//! 1. every shard must load fully into memory → cap edges per shard;
//! 2. edges per shard should be balanced.
//!
//! Given the in-degree array, a greedy sweep packs consecutive vertices
//! until the running edge count would exceed the target, then cuts.  The
//! target is `min(max_edges_per_shard, ceil(|E| / ceil(|E|/max)))` so the
//! final shard is not pathologically small.

use crate::graph::VertexId;

/// Compute interval boundaries from the in-degree array.
///
/// Returns `intervals` with `intervals[0] == 0`,
/// `intervals.last() == in_deg.len()`, and every `[i, i+1)` shard holding at
/// most `max_edges_per_shard` edges — except where a single vertex's
/// in-degree alone exceeds the cap, in which case that vertex gets a
/// dedicated interval (the engine's kernel path then splits its edge list
/// across multiple kernel calls).
pub fn compute_intervals(in_deg: &[u32], max_edges_per_shard: usize) -> Vec<VertexId> {
    let n = in_deg.len();
    if n == 0 {
        return vec![0, 0];
    }
    let total: u64 = in_deg.iter().map(|&d| d as u64).sum();
    let cap = max_edges_per_shard.max(1) as u64;
    // balance: number of shards needed at the cap, then equalize
    let num_shards = total.div_ceil(cap).max(1);
    let target = total.div_ceil(num_shards).max(1);

    let cut_at = target.min(cap);
    let mut intervals: Vec<VertexId> = vec![0];
    let mut acc: u64 = 0;
    for (v, &d) in in_deg.iter().enumerate() {
        let d = d as u64;
        if d > cut_at {
            // unsplittable hub: dedicated single-vertex interval
            if acc > 0 || *intervals.last().unwrap() < v as VertexId {
                intervals.push(v as VertexId);
            }
            intervals.push(v as VertexId + 1);
            acc = 0;
            continue;
        }
        if acc > 0 && acc + d > cut_at {
            intervals.push(v as VertexId);
            acc = 0;
        }
        acc += d;
    }
    intervals.push(n as VertexId);
    // guard: dedupe a trailing boundary if the loop cut exactly at n
    intervals.dedup();
    if intervals.len() == 1 {
        intervals.push(n as VertexId);
    }
    intervals
}

/// Edges per shard implied by `intervals` over `in_deg` (for tests/benches).
pub fn shard_edge_counts(in_deg: &[u32], intervals: &[VertexId]) -> Vec<u64> {
    intervals
        .windows(2)
        .map(|w| {
            in_deg[w[0] as usize..w[1] as usize]
                .iter()
                .map(|&d| d as u64)
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    fn validate(in_deg: &[u32], intervals: &[VertexId], cap: usize) {
        assert!(intervals.len() >= 2);
        assert_eq!(intervals[0], 0);
        assert_eq!(*intervals.last().unwrap() as usize, in_deg.len());
        assert!(intervals.windows(2).all(|w| w[0] < w[1]), "{intervals:?}");
        for (i, &count) in shard_edge_counts(in_deg, intervals).iter().enumerate() {
            let width = intervals[i + 1] - intervals[i];
            // single-vertex intervals may exceed the cap (unsplittable)
            if width > 1 {
                assert!(count <= cap as u64, "shard {i} has {count} edges > cap {cap}");
            }
        }
    }

    #[test]
    fn uniform_degrees_balanced() {
        let in_deg = vec![10u32; 100]; // 1000 edges
        let intervals = compute_intervals(&in_deg, 250);
        validate(&in_deg, &intervals, 250);
        let counts = shard_edge_counts(&in_deg, &intervals);
        assert!(counts.len() >= 4);
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 20, "unbalanced: {counts:?}");
    }

    #[test]
    fn single_shard_when_under_cap() {
        let in_deg = vec![1u32; 50];
        let intervals = compute_intervals(&in_deg, 1000);
        assert_eq!(intervals, vec![0, 50]);
    }

    #[test]
    fn hub_vertex_gets_own_interval() {
        let mut in_deg = vec![1u32; 10];
        in_deg[5] = 10_000; // hub exceeding any cap
        let intervals = compute_intervals(&in_deg, 100);
        validate(&in_deg, &intervals, 100);
        // vertex 5 must be alone in its interval
        let pos = intervals.iter().position(|&b| b == 5).expect("cut before hub");
        assert_eq!(intervals[pos + 1], 6, "hub interval is [5,6): {intervals:?}");
    }

    #[test]
    fn empty_and_zero_degree() {
        assert_eq!(compute_intervals(&[], 10), vec![0, 0]);
        let in_deg = vec![0u32; 5];
        let intervals = compute_intervals(&in_deg, 10);
        assert_eq!(intervals, vec![0, 5]);
    }

    #[test]
    fn prop_partition_invariants() {
        prop::check(0x1AB5, 60, |g| {
            let n = g.usize_in(1, 500);
            let mut rng = Xoshiro256::seed_from_u64(g.u64());
            let in_deg: Vec<u32> = (0..n).map(|_| rng.gen_range(40) as u32).collect();
            let cap = g.usize_in(8, 200);
            let intervals = compute_intervals(&in_deg, cap);
            validate(&in_deg, &intervals, cap);
            // total edges preserved
            let total: u64 = in_deg.iter().map(|&d| d as u64).sum();
            let sum: u64 = shard_edge_counts(&in_deg, &intervals).iter().sum();
            assert_eq!(total, sum);
        });
    }
}
