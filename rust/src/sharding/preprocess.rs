//! The 4-step preprocessing pipeline (paper §II-B) turning an edge list into
//! a `<name>.gmp/` dataset directory.
//!
//! Step 1 — scan: degrees + graph info.
//! Step 2 — intervals: balanced, memory-bounded (see [`super::intervals`]).
//! Step 3 — bucket edges by destination interval ("append each edge to a
//!          shard file"); in-memory buckets here since the scaled datasets
//!          fit, but the bucketing is still per-shard to mirror the paper.
//! Step 4 — CSR transform + persist shards, Bloom filters, metadata.

use anyhow::{Context, Result};

use crate::bloom::BloomFilter;
use crate::graph::csr::Csr;
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::storage::format::frame;
use crate::storage::property::Property;
use crate::storage::vertexinfo::VertexInfo;
use crate::storage::{io, shardfile, DatasetDir};

pub(crate) const BLOOM_MAGIC: &[u8; 4] = b"GMBF";
pub(crate) const BLOOM_VERSION: u32 = 1;

/// Preprocessing knobs.
#[derive(Debug, Clone)]
pub struct PreprocessConfig {
    /// Edge cap per shard. The paper uses 18–22M edges (~80 MB); the default
    /// here matches the AOT kernel geometry so every shard is executable in
    /// one kernel call (`runtime::geometry::E_MAX`).
    pub max_edges_per_shard: usize,
    /// Bloom filter target false-positive rate (per shard).
    pub bloom_fpr: f64,
}

impl Default for PreprocessConfig {
    fn default() -> Self {
        Self {
            max_edges_per_shard: crate::runtime::geometry::E_MAX,
            bloom_fpr: 0.01,
        }
    }
}

/// Summary returned by [`preprocess`].
#[derive(Debug, Clone)]
pub struct PreprocessOutput {
    pub property: Property,
    pub shard_edge_counts: Vec<u64>,
    pub bloom_bytes: u64,
}

/// Run the full pipeline. `num_vertices` may exceed the max id + 1 (isolated
/// trailing vertices are allowed, as in the paper's datasets).
pub fn preprocess(
    name: &str,
    edges: &[Edge],
    num_vertices: usize,
    out: &DatasetDir,
    cfg: &PreprocessConfig,
) -> Result<PreprocessOutput> {
    preprocess_weighted(name, edges, &[], num_vertices, out, cfg)
}

/// [`preprocess`] with an explicit per-edge weight lane (parallel to
/// `edges`; empty = unweighted).  Weights ride through the destination
/// bucketing into each shard's CSR, so `gather` sees the real `val(u,v)`.
pub fn preprocess_weighted(
    name: &str,
    edges: &[Edge],
    weights: &[Weight],
    num_vertices: usize,
    out: &DatasetDir,
    cfg: &PreprocessConfig,
) -> Result<PreprocessOutput> {
    anyhow::ensure!(
        weights.is_empty() || weights.len() == edges.len(),
        "weights must be empty or parallel to edges ({} vs {})",
        weights.len(),
        edges.len()
    );
    let weighted = !weights.is_empty();
    // interval width is additionally capped by the kernel geometry so the
    // xla engine can run any shard in one call
    let v_cap = crate::runtime::geometry::V_MAX;
    out.create()?;

    // -- step 1: scan ---------------------------------------------------
    for &(s, d) in edges {
        anyhow::ensure!(
            (s as usize) < num_vertices && (d as usize) < num_vertices,
            "edge ({s},{d}) outside vertex range {num_vertices}"
        );
    }
    let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
    let info = degrees.info(edges.len() as u64);

    // -- step 2: intervals -----------------------------------------------
    let mut intervals =
        super::intervals::compute_intervals(&degrees.in_deg, cfg.max_edges_per_shard);
    intervals = split_wide_intervals(&intervals, v_cap);

    // -- step 3: bucket edges by destination interval ---------------------
    let num_shards = intervals.len() - 1;
    let mut buckets: Vec<Vec<Edge>> = vec![Vec::new(); num_shards];
    let mut wbuckets: Vec<Vec<Weight>> = vec![Vec::new(); num_shards];
    // interval lookup: binary search over boundaries
    let shard_of = |v: VertexId| -> usize {
        match intervals.binary_search(&v) {
            Ok(i) => i.min(num_shards - 1),
            Err(i) => i - 1,
        }
    };
    for (k, &(s, d)) in edges.iter().enumerate() {
        let i = shard_of(d);
        buckets[i].push((s, d));
        if weighted {
            wbuckets[i].push(weights[k]);
        }
    }

    // -- step 4: CSR transform + persist ---------------------------------
    let mut shard_edge_counts = Vec::with_capacity(num_shards);
    let mut bloom_bytes = 0u64;
    for (i, bucket) in buckets.iter().enumerate() {
        let (lo, hi) = (intervals[i], intervals[i + 1]);
        let csr = Csr::from_edges_weighted(lo, hi, bucket, &wbuckets[i]);
        csr.validate().with_context(|| format!("shard {i}"))?;
        shardfile::save(&csr, &out.shard_path(i))?;
        shard_edge_counts.push(csr.num_edges() as u64);

        // Bloom filter over *source* vertices of the shard's edges
        let mut bloom = BloomFilter::with_capacity(bucket.len().max(1), cfg.bloom_fpr);
        for &(s, _) in bucket {
            bloom.insert(s as u64);
        }
        let framed = frame(BLOOM_MAGIC, BLOOM_VERSION, &bloom.to_bytes());
        bloom_bytes += framed.len() as u64;
        io::write_file(&out.bloom_path(i), &framed)?;
    }

    let property = Property { name: name.to_string(), info, intervals };
    property.save(&out.property_path())?;
    VertexInfo::new(degrees).save(&out.vertexinfo_path())?;

    Ok(PreprocessOutput { property, shard_edge_counts, bloom_bytes })
}

/// Load a framed Bloom filter from an arbitrary path (base blooms and the
/// per-epoch rebuilds of mutated shards share the same `GMBF` framing).
pub fn load_bloom_file(path: &std::path::Path) -> Result<BloomFilter> {
    let buf = io::read_file(path)?;
    let (version, payload) = crate::storage::format::unframe(BLOOM_MAGIC, &buf)?;
    anyhow::ensure!(version == BLOOM_VERSION, "bloom version {version}");
    BloomFilter::from_bytes(payload)
}

/// Load a shard's base Bloom filter.
pub fn load_bloom(dir: &DatasetDir, shard: usize) -> Result<BloomFilter> {
    load_bloom_file(&dir.bloom_path(shard))
}

/// Enforce the kernel-geometry vertex cap by splitting wide intervals.
pub(crate) fn split_wide_intervals(intervals: &[VertexId], v_cap: usize) -> Vec<VertexId> {
    let mut out = Vec::with_capacity(intervals.len());
    out.push(intervals[0]);
    for w in intervals.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let mut cur = lo;
        while (hi - cur) as usize > v_cap {
            cur += v_cap as VertexId;
            out.push(cur);
        }
        out.push(hi);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::prop;

    fn tmpdir(tag: &str) -> DatasetDir {
        let d = std::env::temp_dir().join(format!("gmp_prep_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        DatasetDir::new(d)
    }

    #[test]
    fn pipeline_small_graph() {
        let edges = vec![(0u32, 1u32), (1, 2), (2, 0), (0, 2), (3, 1)];
        let dir = tmpdir("small");
        let out = preprocess("small", &edges, 4, &dir, &PreprocessConfig::default()).unwrap();
        assert_eq!(out.property.info.num_edges, 5);
        assert_eq!(out.property.info.num_vertices, 4);
        assert!(dir.exists());
        // reload everything and check edge preservation
        let p = Property::load(&dir.property_path()).unwrap();
        let mut all = Vec::new();
        for i in 0..p.num_shards() {
            let csr = shardfile::load(&dir.shard_path(i)).unwrap();
            assert_eq!((csr.lo, csr.hi), p.interval(i));
            all.extend(csr.to_edges());
        }
        all.sort_unstable();
        let mut want = edges.clone();
        want.sort_unstable();
        assert_eq!(all, want);
    }

    #[test]
    fn bloom_covers_sources() {
        let edges = generator::erdos_renyi(200, 2000, 11);
        let dir = tmpdir("bloom");
        let out = preprocess("b", &edges, 200, &dir, &PreprocessConfig::default()).unwrap();
        let p = &out.property;
        for i in 0..p.num_shards() {
            let bloom = load_bloom(&dir, i).unwrap();
            let csr = shardfile::load(&dir.shard_path(i)).unwrap();
            for (_, srcs) in csr.iter_rows() {
                for &s in srcs {
                    assert!(bloom.contains(s as u64), "bloom false negative");
                }
            }
        }
    }

    #[test]
    fn shards_respect_caps() {
        let edges = generator::rmat(12, 30_000, generator::RmatParams::default(), 5);
        let dir = tmpdir("caps");
        let cfg = PreprocessConfig { max_edges_per_shard: 4096, bloom_fpr: 0.01 };
        let out = preprocess("caps", &edges, 1 << 12, &dir, &cfg).unwrap();
        for (i, w) in out.property.intervals.windows(2).enumerate() {
            let width = (w[1] - w[0]) as usize;
            assert!(width <= crate::runtime::geometry::V_MAX, "interval {i} too wide");
            if width > 1 {
                assert!(
                    out.shard_edge_counts[i] <= 4096,
                    "shard {i}: {} edges",
                    out.shard_edge_counts[i]
                );
            }
        }
    }

    #[test]
    fn rejects_out_of_range_edges() {
        let dir = tmpdir("oob");
        assert!(preprocess("x", &[(0, 9)], 5, &dir, &PreprocessConfig::default()).is_err());
    }

    #[test]
    fn rejects_mismatched_weight_lane() {
        let dir = tmpdir("wlen");
        assert!(preprocess_weighted(
            "x",
            &[(0, 1), (1, 2)],
            &[1.0],
            3,
            &dir,
            &PreprocessConfig::default()
        )
        .is_err());
    }

    #[test]
    fn weighted_pipeline_preserves_weight_per_edge() {
        let edges = generator::erdos_renyi(120, 900, 23);
        let weights = generator::synth_weights(&edges, 7);
        let dir = tmpdir("weighted");
        let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
        let out = preprocess_weighted("w", &edges, &weights, 120, &dir, &cfg).unwrap();
        let mut got = Vec::new();
        for i in 0..out.property.num_shards() {
            let csr = shardfile::load(&dir.shard_path(i)).unwrap();
            assert!(csr.is_weighted());
            got.extend(csr.to_wedges());
        }
        let mut want: Vec<(u32, u32, f32)> = edges
            .iter()
            .zip(&weights)
            .map(|(&(s, d), &w)| (s, d, w))
            .collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want);
    }

    #[test]
    fn prop_every_edge_in_exactly_one_shard() {
        prop::check(0x9E9E, 15, |g| {
            let n = g.usize_in(2, 300);
            let m = g.usize_in(0, 1500);
            let edges = g.edges(n, m);
            let dir = tmpdir(&format!("p{}", g.case_seed));
            let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.05 };
            let out = preprocess("p", &edges, n, &dir, &cfg).unwrap();
            let total: u64 = out.shard_edge_counts.iter().sum();
            assert_eq!(total, m as u64);
            // intervals disjoint + covering
            let iv = &out.property.intervals;
            assert_eq!(iv[0], 0);
            assert_eq!(*iv.last().unwrap() as usize, n);
            assert!(iv.windows(2).all(|w| w[0] < w[1]));
            let _ = std::fs::remove_dir_all(&dir.root);
        });
    }
}
