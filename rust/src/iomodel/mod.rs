//! Table II: the closed-form per-iteration I/O + memory analysis of the
//! five computation models.
//!
//! | model | data read              | data write        | memory            |
//! |-------|------------------------|-------------------|-------------------|
//! | PSW   | C·V + 2(C+D)·E         | C·V + 2(C+D)·E    | (C·V+2(C+D)·E)/P  |
//! | ESG   | C·V + (C+D)·E          | C·V + C·E         | C·V/P             |
//! | VSP   | C(1+δ)·V + D·E         | C·V               | C(2+δ)·V/P        |
//! | DSW   | C·√P·V + D·E           | C·√P·V            | 2C·V/√P           |
//! | VSW   | θ·D·E                  | 0                 | 2C·V + N·D·E/P    |
//!
//! with `C` bytes/vertex-value, `D` bytes/edge, `δ ≈ (1-e^(-d_avg/P))·P`,
//! `θ` the cache miss ratio, `N` CPU cores.  `benches/table2_iomodel.rs`
//! checks these predictions against the byte counters the engines actually
//! report.

/// Model inputs.
#[derive(Debug, Clone, Copy)]
pub struct ModelParams {
    /// |V|
    pub v: u64,
    /// |E|
    pub e: u64,
    /// Number of shards / partitions / grid blocks.
    pub p: u64,
    /// Bytes per vertex value (C). We use f32 ⇒ 4.
    pub c: u64,
    /// Bytes per edge record (D). Raw (src,dst) pairs ⇒ 8; CSR col entry ⇒ 4.
    pub d: u64,
    /// CPU cores (N).
    pub n_cores: u64,
    /// Cache miss ratio θ ∈ [0,1] (VSW only).
    pub theta: f64,
}

impl ModelParams {
    pub fn d_avg(&self) -> f64 {
        self.e as f64 / self.v.max(1) as f64
    }

    /// δ ≈ (1 − e^(−d_avg/P))·P (Table II footnote).
    pub fn delta(&self) -> f64 {
        let p = self.p.max(1) as f64;
        (1.0 - (-self.d_avg() / p).exp()) * p
    }
}

/// Per-iteration prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub read: f64,
    pub write: f64,
    pub memory: f64,
}

/// The five computation models of Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Model {
    Psw,
    Esg,
    Vsp,
    Dsw,
    Vsw,
}

impl Model {
    pub const ALL: [Model; 5] = [Model::Psw, Model::Esg, Model::Vsp, Model::Dsw, Model::Vsw];

    pub fn name(&self) -> &'static str {
        match self {
            Model::Psw => "PSW (GraphChi)",
            Model::Esg => "ESG (X-Stream)",
            Model::Vsp => "VSP (VENUS)",
            Model::Dsw => "DSW (GridGraph)",
            Model::Vsw => "VSW (GraphMP)",
        }
    }

    /// Table II row for this model.
    pub fn predict(&self, p: &ModelParams) -> Prediction {
        let (v, e) = (p.v as f64, p.e as f64);
        let (c, d) = (p.c as f64, p.d as f64);
        let shards = p.p.max(1) as f64;
        match self {
            Model::Psw => Prediction {
                read: c * v + 2.0 * (c + d) * e,
                write: c * v + 2.0 * (c + d) * e,
                memory: (c * v + 2.0 * (c + d) * e) / shards,
            },
            Model::Esg => Prediction {
                read: c * v + (c + d) * e,
                write: c * v + c * e,
                memory: c * v / shards,
            },
            Model::Vsp => Prediction {
                read: c * (1.0 + p.delta()) * v + d * e,
                write: c * v,
                memory: c * (2.0 + p.delta()) * v / shards,
            },
            Model::Dsw => {
                let sqrt_p = shards.sqrt();
                Prediction {
                    read: c * sqrt_p * v + d * e,
                    write: c * sqrt_p * v,
                    memory: 2.0 * c * v / sqrt_p,
                }
            }
            Model::Vsw => Prediction {
                read: p.theta * d * e,
                write: 0.0,
                memory: 2.0 * c * v + p.n_cores as f64 * d * e / shards,
            },
        }
    }
}

/// Relative error |measured − predicted| / predicted (predicted > 0).
pub fn rel_error(measured: f64, predicted: f64) -> f64 {
    if predicted == 0.0 {
        if measured == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (measured - predicted).abs() / predicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ModelParams {
        ModelParams { v: 1000, e: 20_000, p: 16, c: 4, d: 8, n_cores: 4, theta: 1.0 }
    }

    #[test]
    fn vsw_reads_least_writes_nothing() {
        let p = params();
        let vsw = Model::Vsw.predict(&p);
        assert_eq!(vsw.write, 0.0);
        for m in [Model::Psw, Model::Esg, Model::Vsp, Model::Dsw] {
            let other = m.predict(&p);
            assert!(other.read > vsw.read, "{} should read more", m.name());
            assert!(other.write > vsw.write);
        }
    }

    #[test]
    fn vsw_with_cache_hits_reads_less() {
        let mut p = params();
        p.theta = 1.0;
        let cold = Model::Vsw.predict(&p);
        p.theta = 0.25;
        let warm = Model::Vsw.predict(&p);
        assert!((warm.read - 0.25 * cold.read).abs() < 1e-9);
    }

    #[test]
    fn psw_is_heaviest() {
        let p = params();
        let psw = Model::Psw.predict(&p);
        for m in [Model::Esg, Model::Vsp, Model::Dsw, Model::Vsw] {
            assert!(psw.read >= m.predict(&p).read);
            assert!(psw.write >= m.predict(&p).write);
        }
    }

    #[test]
    fn vsw_memory_exceeds_ooc_models() {
        // the paper's trade-off: lowest I/O at the cost of highest memory
        let p = params();
        let vsw = Model::Vsw.predict(&p);
        for m in [Model::Psw, Model::Esg, Model::Vsp] {
            assert!(
                vsw.memory > m.predict(&p).memory,
                "VSW should out-remember {}",
                m.name()
            );
        }
    }

    #[test]
    fn delta_matches_formula() {
        let p = params();
        let d_avg = 20.0;
        let want = (1.0 - (-d_avg / 16.0f64).exp()) * 16.0;
        assert!((p.delta() - want).abs() < 1e-12);
    }

    #[test]
    fn rel_error_basics() {
        assert_eq!(rel_error(110.0, 100.0), 0.1);
        assert_eq!(rel_error(0.0, 0.0), 0.0);
        assert!(rel_error(1.0, 0.0).is_infinite());
    }
}
