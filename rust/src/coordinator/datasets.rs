//! Dataset registry: the paper's four webgraphs at ~1000× reduced scale
//! (DESIGN.md §3 substitution), generated deterministically with R-MAT so
//! the power-law skew matches.
//!
//! | paper    | |V|   | |E|    | here       | |V|    | |E|    | avg deg |
//! |----------|-------|--------|------------|--------|--------|---------|
//! | Twitter  | 42M   | 1.5B   | twitter-s  | 42K    | 1.5M   | ~35     |
//! | UK-2007  | 134M  | 5.5B   | uk2007-s   | 131K   | 5.5M   | ~41     |
//! | UK-2014  | 788M  | 47.6B  | uk2014-s   | 786K   | 47.6M  | ~60     |
//! | EU-2015  | 1.1B  | 91.8B  | eu2015-s   | 1.05M  | 91.8M  | ~87     |
//!
//! Vertex counts are rounded to powers of two (R-MAT requirement); edge
//! counts keep the paper's average degree.  `tiny`/`small` exist for tests
//! and quick demos.

use crate::graph::generator::{self, RmatParams};
use crate::graph::Edge;

/// A registered synthetic dataset.
#[derive(Debug, Clone, Copy)]
pub struct Dataset {
    pub name: &'static str,
    /// Paper dataset this one scales down (if any).
    pub stands_in_for: &'static str,
    /// R-MAT scale: |V| = 2^scale.
    pub scale: u32,
    pub num_edges: u64,
    pub seed: u64,
}

impl Dataset {
    pub fn num_vertices(&self) -> usize {
        1usize << self.scale
    }

    pub fn avg_degree(&self) -> f64 {
        self.num_edges as f64 / self.num_vertices() as f64
    }

    /// Generate the edge list (deterministic per seed).
    pub fn generate(&self) -> Vec<Edge> {
        generator::rmat(self.scale, self.num_edges, RmatParams::default(), self.seed)
    }

    /// Look up by name.
    pub fn by_name(name: &str) -> anyhow::Result<&'static Dataset> {
        DATASETS
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown dataset {name:?} (available: {})",
                    DATASETS.iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
                )
            })
    }
}

/// The registry. Edge counts follow the paper's average degrees.
pub static DATASETS: [Dataset; 6] = [
    Dataset { name: "tiny", stands_in_for: "-", scale: 8, num_edges: 4_000, seed: 42 },
    Dataset { name: "small", stands_in_for: "-", scale: 12, num_edges: 120_000, seed: 42 },
    Dataset {
        name: "twitter-s",
        stands_in_for: "Twitter (42M v, 1.5B e)",
        scale: 15, // 32K vertices ≈ 42K target; 1.2M edges keeps avg deg ≈ 36
        num_edges: 1_200_000,
        seed: 1001,
    },
    Dataset {
        name: "uk2007-s",
        stands_in_for: "UK-2007 (134M v, 5.5B e)",
        scale: 17, // 131K vertices
        num_edges: 5_500_000,
        seed: 1002,
    },
    Dataset {
        name: "uk2014-s",
        stands_in_for: "UK-2014 (788M v, 47.6B e)",
        scale: 19, // 524K vertices (slightly under the 786K ratio)
        num_edges: 31_000_000,
        seed: 1003,
    },
    Dataset {
        name: "eu2015-s",
        stands_in_for: "EU-2015 (1.1B v, 91.8B e)",
        scale: 20, // 1.05M vertices
        num_edges: 91_000_000,
        seed: 1004,
    },
];

/// The four paper datasets in evaluation order.
pub fn paper_datasets() -> Vec<&'static Dataset> {
    ["twitter-s", "uk2007-s", "uk2014-s", "eu2015-s"]
        .iter()
        .map(|n| Dataset::by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Degrees;

    #[test]
    fn registry_lookup() {
        assert!(Dataset::by_name("twitter-s").is_ok());
        assert!(Dataset::by_name("nope").is_err());
        assert_eq!(paper_datasets().len(), 4);
    }

    #[test]
    fn average_degrees_match_paper_order() {
        // paper: Twitter 35.3, UK-2007 41.2, UK-2014 60.4, EU-2015 85.7 —
        // scaled counterparts must preserve the ordering and magnitudes
        let avg: Vec<f64> = paper_datasets().iter().map(|d| d.avg_degree()).collect();
        assert!(avg.windows(2).all(|w| w[0] < w[1]), "{avg:?}");
        assert!(avg[0] > 20.0 && avg[3] > 60.0, "{avg:?}");
    }

    #[test]
    fn tiny_generates_power_law() {
        let d = Dataset::by_name("tiny").unwrap();
        let edges = d.generate();
        assert_eq!(edges.len() as u64, d.num_edges);
        let deg = Degrees::from_edges(d.num_vertices(), edges.iter().copied());
        let max_in = *deg.in_deg.iter().max().unwrap() as f64;
        assert!(max_in > 5.0 * d.avg_degree(), "not skewed");
    }
}
