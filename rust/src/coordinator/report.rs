//! Report output: append bench tables to a markdown log so EXPERIMENTS.md
//! can cite machine-generated numbers, and format helpers shared by the
//! bench binaries.

use std::path::Path;

use anyhow::Result;

use crate::util::bench::Table;

/// Append a rendered table (with a timestamp header) to `path`.
pub fn append_markdown(path: &Path, table: &Table) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    let epoch = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    writeln!(f, "\n<!-- generated at unix:{epoch} -->")?;
    f.write_all(table.to_markdown().as_bytes())?;
    Ok(())
}

/// Standard results file written by bench targets.
pub fn results_path() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results.md")
}

/// Format a speedup ratio the way Table III prints them.
pub fn ratio(base: f64, other: f64) -> String {
    if base <= 0.0 {
        "-".into()
    } else {
        format!("{:.1}", other / base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(2.0, 25.0), "12.5");
        assert_eq!(ratio(0.0, 10.0), "-");
    }

    #[test]
    fn append_markdown_writes() {
        let p = std::env::temp_dir().join(format!("gmp_report_{}.md", std::process::id()));
        let _ = std::fs::remove_file(&p);
        let mut t = Table::new("t", &["a"]);
        t.row(&["1".into()]);
        append_markdown(&p, &t).unwrap();
        append_markdown(&p, &t).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.matches("### t").count(), 2);
    }
}
