//! Coordination layer: dataset registry, experiment drivers and report
//! output shared by the CLI, the examples and every bench target.

pub mod benchjson;
pub mod cli;
pub mod datasets;
pub mod experiment;
pub mod report;

pub use datasets::{Dataset, DATASETS};
pub use experiment::{ensure_dataset, run_graphmp, run_graphmp_adaptive, GraphMpVariant};
