//! Minimal CLI argument parser (the offline crate set has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args and generated usage text.  Just enough for the `graphmp` binary and
//! the bench binaries' `--quick`/`--dataset` options.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: flags + positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `bool_flags` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bool_flags: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&flag) {
                    args.bools.push(flag.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("--{flag} expects a value"))?;
                    args.flags.insert(flag.to_string(), v);
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short flags not supported: {a}");
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required --{key}"))
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.bools.iter().any(|b| b == flag)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = Args::parse(
            v(&["run", "--app", "pagerank", "--iters=10", "--quick"]),
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["run"]);
        assert_eq!(a.get("app"), Some("pagerank"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 10);
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(v(&["--app"]), &[]).is_err());
    }

    #[test]
    fn req_and_defaults() {
        let a = Args::parse(v(&["--x", "1"]), &[]).unwrap();
        assert!(a.req("x").is_ok());
        assert!(a.req("y").is_err());
        assert_eq!(a.get_or("z", "d"), "d");
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.0);
    }

    #[test]
    fn bad_numbers_error() {
        let a = Args::parse(v(&["--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
