//! Machine-readable bench records (`BENCH_*.json`) and the CI perf gate.
//!
//! Every `--quick` bench driver appends one record — wall seconds, io-wait
//! fraction, cache hit ratio — to the file named by `GRAPHMP_BENCH_JSON`
//! (CI points it at `BENCH_pr.json`).  `graphmp bench-compare` then diffs
//! that file against the committed `BENCH_baseline.json` and fails the job
//! on a regression, so the perf trajectory is recorded PR over PR instead
//! of regressions shipping silently.
//!
//! File format: one JSON object keyed by bench name,
//! `{"fig5_selective": {"wall_secs": 1.2, "io_wait_fraction": 0.31,
//! "cache_hit_ratio": 0.98}, ...}` — parsed with the in-tree
//! [`crate::util::json`] (the offline crate set has no serde).
//!
//! Gate semantics: a bench regresses when its wall time exceeds
//! `baseline * (1 + tolerance)` **and** the absolute slowdown exceeds
//! `min_abs_secs` (quick benches run ~seconds; the absolute floor keeps
//! scheduler noise on a 50 ms bench from tripping a 25 % gate).  A bench
//! present in the baseline but absent from the current file also fails —
//! silently dropping a bench must not read as "no regression".  The io-wait
//! fraction and hit ratio ride along for the trajectory record but are not
//! gated: they are diagnostic, and machine-dependent enough that gating
//! them would gate the hardware.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::engine::RunStats;
use crate::util::json::Json;

/// One bench's recorded numbers.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub wall_secs: f64,
    pub io_wait_fraction: f64,
    pub cache_hit_ratio: f64,
    /// Shard-decode nanoseconds of the representative run (payload
    /// decompression + delta-varint planning + layout validation) — the
    /// decode half of the fig7 compressed-domain split.  Diagnostic, not
    /// gated; 0 for records written before the lane existed.
    pub decode_ns: f64,
}

/// Round to µs-ish precision so the JSON stays diff-friendly.
fn round6(x: f64) -> f64 {
    (x * 1e6).round() / 1e6
}

impl BenchRecord {
    /// Build a record from a bench's overall wall time plus the
    /// representative run's engine statistics.
    pub fn from_stats(name: &str, wall: Duration, stats: &RunStats) -> Self {
        Self {
            name: name.to_string(),
            wall_secs: round6(wall.as_secs_f64()),
            io_wait_fraction: round6(stats.io_wait_fraction()),
            cache_hit_ratio: round6(stats.cache_hit_ratio()),
            decode_ns: stats.total_decode_ns() as f64,
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("wall_secs".to_string(), Json::Num(self.wall_secs));
        m.insert("io_wait_fraction".to_string(), Json::Num(self.io_wait_fraction));
        m.insert("cache_hit_ratio".to_string(), Json::Num(self.cache_hit_ratio));
        m.insert("decode_ns".to_string(), Json::Num(self.decode_ns));
        Json::Obj(m)
    }
}

/// Where `--quick` bench drivers should record to, if anywhere
/// (`GRAPHMP_BENCH_JSON`).
pub fn env_path() -> Option<PathBuf> {
    std::env::var_os("GRAPHMP_BENCH_JSON").map(PathBuf::from)
}

/// Load a `BENCH_*.json` file into name-keyed records.
pub fn load(path: &Path) -> Result<BTreeMap<String, BenchRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let root = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    let obj = root
        .as_obj()
        .with_context(|| format!("{}: top level must be an object", path.display()))?;
    let mut out = BTreeMap::new();
    for (name, v) in obj {
        let rec = BenchRecord {
            name: name.clone(),
            wall_secs: v
                .req("wall_secs")
                .with_context(|| format!("bench {name:?}"))?
                .as_f64()
                .with_context(|| format!("bench {name:?}: wall_secs must be a number"))?,
            io_wait_fraction: v.get("io_wait_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            cache_hit_ratio: v.get("cache_hit_ratio").and_then(Json::as_f64).unwrap_or(0.0),
            decode_ns: v.get("decode_ns").and_then(Json::as_f64).unwrap_or(0.0),
        };
        out.insert(name.clone(), rec);
    }
    Ok(out)
}

/// Insert/overwrite one record in `path` (creating the file if needed).
/// Bench drivers run sequentially in CI, so read-modify-write suffices.
pub fn append_record(path: &Path, rec: &BenchRecord) -> Result<()> {
    let mut map = if path.exists() {
        load(path)?
    } else {
        BTreeMap::new()
    };
    map.insert(rec.name.clone(), rec.clone());
    let obj: BTreeMap<String, Json> =
        map.iter().map(|(k, v)| (k.clone(), v.to_json())).collect();
    std::fs::write(path, format!("{}\n", Json::Obj(obj)))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Record `rec` if `GRAPHMP_BENCH_JSON` is set; no-op otherwise so local
/// bench runs stay side-effect free.
pub fn record_if_requested(rec: &BenchRecord) -> Result<()> {
    if let Some(path) = env_path() {
        append_record(&path, rec)?;
        eprintln!(
            "[benchjson] {} -> {} (wall {:.3}s, io_wait {:.1}%, hit {:.1}%)",
            rec.name,
            path.display(),
            rec.wall_secs,
            rec.io_wait_fraction * 100.0,
            rec.cache_hit_ratio * 100.0
        );
    }
    Ok(())
}

/// One compared bench, structured so alternative renderings (the CI job
/// summary's markdown table) don't have to re-parse the human lines.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub name: String,
    pub base_wall: f64,
    /// `None` = present in baseline but missing from the current run.
    pub cur_wall: Option<f64>,
    pub status: RowStatus,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowStatus {
    Ok,
    Regressed,
    /// Current is far below baseline — the gate has dead slack.
    Stale,
    Missing,
}

impl RowStatus {
    fn label(self) -> &'static str {
        match self {
            RowStatus::Ok => "ok",
            RowStatus::Regressed => "**regressed**",
            RowStatus::Stale => "stale baseline",
            RowStatus::Missing => "**missing**",
        }
    }
}

/// Outcome of a baseline-vs-current comparison.
#[derive(Debug, Default)]
pub struct CompareReport {
    /// Benches present in both files.
    pub compared: usize,
    /// Human-readable per-bench lines (all benches, regressed or not).
    pub lines: Vec<String>,
    /// One message per failed gate; empty = pass.
    pub regressions: Vec<String>,
    /// Benches whose current wall time is far *below* baseline: the
    /// baseline is stale and the gate has slack it shouldn't have.  Not a
    /// failure (a genuine speedup looks the same), but surfaced loudly so
    /// the baseline gets refreshed and the gate stays tight.
    pub stale_baseline: Vec<String>,
    /// Structured per-bench rows (baseline order), one per baseline bench.
    pub rows: Vec<CompareRow>,
}

impl CompareReport {
    /// Render the delta table as GitHub-flavored markdown — pointed at
    /// `$GITHUB_STEP_SUMMARY` by the CI bench-compare step.
    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "### bench-compare\n\n\
             | bench | baseline (s) | current (s) | delta | status |\n\
             |---|---:|---:|---:|---|\n",
        );
        for r in &self.rows {
            match r.cur_wall {
                Some(cur) => {
                    let delta = if r.base_wall > 0.0 {
                        (cur / r.base_wall - 1.0) * 100.0
                    } else {
                        0.0
                    };
                    s.push_str(&format!(
                        "| {} | {:.3} | {:.3} | {:+.1}% | {} |\n",
                        r.name,
                        r.base_wall,
                        cur,
                        delta,
                        r.status.label()
                    ));
                }
                None => s.push_str(&format!(
                    "| {} | {:.3} | — | — | {} |\n",
                    r.name,
                    r.base_wall,
                    r.status.label()
                )),
            }
        }
        s
    }
}

/// Diff `current` against `baseline` under the gate semantics documented
/// at module level.
pub fn compare(
    baseline: &BTreeMap<String, BenchRecord>,
    current: &BTreeMap<String, BenchRecord>,
    tolerance: f64,
    min_abs_secs: f64,
) -> CompareReport {
    let mut report = CompareReport::default();
    for (name, base) in baseline {
        let Some(cur) = current.get(name) else {
            report
                .regressions
                .push(format!("{name}: present in baseline but missing from current run"));
            report.rows.push(CompareRow {
                name: name.clone(),
                base_wall: base.wall_secs,
                cur_wall: None,
                status: RowStatus::Missing,
            });
            continue;
        };
        report.compared += 1;
        let ratio = if base.wall_secs > 0.0 {
            cur.wall_secs / base.wall_secs
        } else {
            1.0
        };
        report.lines.push(format!(
            "{name}: wall {:.3}s vs baseline {:.3}s ({:+.1}%), io_wait {:.1}% (was {:.1}%), hit {:.1}% (was {:.1}%)",
            cur.wall_secs,
            base.wall_secs,
            (ratio - 1.0) * 100.0,
            cur.io_wait_fraction * 100.0,
            base.io_wait_fraction * 100.0,
            cur.cache_hit_ratio * 100.0,
            base.cache_hit_ratio * 100.0,
        ));
        let over_ratio = cur.wall_secs > base.wall_secs * (1.0 + tolerance);
        let over_abs = cur.wall_secs - base.wall_secs > min_abs_secs;
        let status = if over_ratio && over_abs {
            report.regressions.push(format!(
                "{name}: {:.3}s > {:.3}s * {:.2} (+{:.3}s)",
                cur.wall_secs,
                base.wall_secs,
                1.0 + tolerance,
                cur.wall_secs - base.wall_secs
            ));
            RowStatus::Regressed
        } else if cur.wall_secs < base.wall_secs * 0.5
            && base.wall_secs - cur.wall_secs > min_abs_secs
        {
            report.stale_baseline.push(format!(
                "{name}: current {:.3}s is under half of baseline {:.3}s — refresh \
                 BENCH_baseline.json or the {:.0}% gate has dead slack",
                cur.wall_secs,
                base.wall_secs,
                tolerance * 100.0
            ));
            RowStatus::Stale
        } else {
            RowStatus::Ok
        };
        report.rows.push(CompareRow {
            name: name.clone(),
            base_wall: base.wall_secs,
            cur_wall: Some(cur.wall_secs),
            status,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, wall: f64) -> BenchRecord {
        BenchRecord {
            name: name.into(),
            wall_secs: wall,
            io_wait_fraction: 0.25,
            cache_hit_ratio: 0.9,
            decode_ns: 1_500.0,
        }
    }

    fn map(recs: &[BenchRecord]) -> BTreeMap<String, BenchRecord> {
        recs.iter().map(|r| (r.name.clone(), r.clone())).collect()
    }

    #[test]
    fn append_then_load_roundtrips() {
        let path = std::env::temp_dir().join(format!("gmp_bj_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        append_record(&path, &rec("fig5", 1.5)).unwrap();
        append_record(&path, &rec("fig6", 2.25)).unwrap();
        // overwrite is idempotent per name
        append_record(&path, &rec("fig5", 1.75)).unwrap();
        let m = load(&path).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["fig5"].wall_secs, 1.75);
        assert_eq!(m["fig6"].wall_secs, 2.25);
        assert!((m["fig6"].io_wait_fraction - 0.25).abs() < 1e-9);
        assert!((m["fig6"].cache_hit_ratio - 0.9).abs() < 1e-9);
        assert!((m["fig6"].decode_ns - 1_500.0).abs() < 1e-9);
        // records written before the decode_ns lane existed load as 0
        std::fs::write(&path, r#"{"legacy": {"wall_secs": 1.0}}"#).unwrap();
        assert_eq!(load(&path).unwrap()["legacy"].decode_ns, 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_malformed() {
        let path = std::env::temp_dir().join(format!("gmp_bj_bad_{}.json", std::process::id()));
        std::fs::write(&path, "[1, 2]").unwrap();
        assert!(load(&path).is_err(), "top-level array must be rejected");
        std::fs::write(&path, r#"{"x": {"io_wait_fraction": 1}}"#).unwrap();
        assert!(load(&path).is_err(), "missing wall_secs must be rejected");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = map(&[rec("a", 2.0), rec("b", 4.0)]);
        let cur = map(&[rec("a", 2.4), rec("b", 3.0), rec("extra", 9.0)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert_eq!(r.compared, 2);
        assert!(r.regressions.is_empty(), "{:?}", r.regressions);
        assert_eq!(r.lines.len(), 2);
    }

    #[test]
    fn gate_fails_past_tolerance_and_on_missing_bench() {
        let base = map(&[rec("a", 2.0), rec("gone", 1.0)]);
        let cur = map(&[rec("a", 2.8)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert_eq!(r.regressions.len(), 2, "{:?}", r.regressions);
        assert!(r.regressions.iter().any(|m| m.contains("gone")));
        assert!(r.regressions.iter().any(|m| m.starts_with("a:")));
    }

    #[test]
    fn stale_baseline_is_flagged_but_not_failed() {
        let base = map(&[rec("a", 5.0), rec("b", 5.0)]);
        let cur = map(&[rec("a", 0.4), rec("b", 4.8)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert!(r.regressions.is_empty());
        assert_eq!(r.stale_baseline.len(), 1, "{:?}", r.stale_baseline);
        assert!(r.stale_baseline[0].starts_with("a:"));
    }

    #[test]
    fn markdown_table_carries_every_baseline_row() {
        let base = map(&[rec("a", 2.0), rec("gone", 1.0), rec("slow", 2.0)]);
        let cur = map(&[rec("a", 2.1), rec("slow", 9.0)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert_eq!(r.rows.len(), 3);
        let md = r.to_markdown();
        assert!(md.contains("| a | 2.000 | 2.100 | +5.0% | ok |"), "{md}");
        assert!(md.contains("| gone | 1.000 | — | — | **missing** |"), "{md}");
        assert!(md.contains("| slow | 2.000 | 9.000 | +350.0% | **regressed** |"), "{md}");
        assert!(md.starts_with("### bench-compare"));
    }

    #[test]
    fn absolute_floor_damps_noise_on_tiny_benches() {
        // 0.05s -> 0.09s is +80% but only 40ms — below the absolute floor
        let base = map(&[rec("micro", 0.05)]);
        let cur = map(&[rec("micro", 0.09)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert!(r.regressions.is_empty());
        // the same ratio at real scale does fail
        let base = map(&[rec("macro", 5.0)]);
        let cur = map(&[rec("macro", 9.0)]);
        let r = compare(&base, &cur, 0.25, 0.25);
        assert_eq!(r.regressions.len(), 1);
    }
}
