//! Experiment drivers shared by benches, examples and the CLI: dataset
//! materialization (generate → preprocess, cached on disk) and the
//! GraphMP-variant runner (GraphMP-C / GraphMP-NC / ±selective-scheduling —
//! the configurations the paper's figures compare).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::apps::{VertexProgram, VertexValue};
use crate::cache::Codec;
use crate::coordinator::datasets::Dataset;
use crate::engine::{Backend, EngineConfig, RunResult, VswEngine};
use crate::graph::{generator, Weight};
use crate::sharding::{preprocess, preprocess_weighted, PreprocessConfig};
use crate::storage::DatasetDir;

/// Seed for the deterministic synthetic weight lane attached to generated
/// datasets (`ensure_dataset_weighted` / `dataset_weights` must agree).
pub const WEIGHT_SEED: u64 = 0xA11CE;

/// Root under which materialized datasets live (override with
/// `GRAPHMP_DATA_DIR`).
pub fn data_root() -> PathBuf {
    std::env::var_os("GRAPHMP_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("graphmp_data"))
}

/// Generate + preprocess `dataset` if not already on disk; returns its
/// directory.  Idempotent across runs (keyed by name).
pub fn ensure_dataset(dataset: &Dataset) -> Result<DatasetDir> {
    let dir = DatasetDir::new(data_root().join(format!("{}.gmp", dataset.name)));
    if dir.exists() {
        return Ok(dir);
    }
    let edges = dataset.generate();
    preprocess(
        dataset.name,
        &edges,
        dataset.num_vertices(),
        &dir,
        &PreprocessConfig::default(),
    )
    .with_context(|| format!("preprocessing {}", dataset.name))?;
    Ok(dir)
}

/// Weighted twin of [`ensure_dataset`]: same edges plus the deterministic
/// synthetic weight lane ([`WEIGHT_SEED`]), materialized under
/// `<name>-w.gmp`.
pub fn ensure_dataset_weighted(dataset: &Dataset) -> Result<DatasetDir> {
    let dir = DatasetDir::new(data_root().join(format!("{}-w.gmp", dataset.name)));
    if dir.exists() {
        return Ok(dir);
    }
    let edges = dataset.generate();
    let weights = generator::synth_weights(&edges, WEIGHT_SEED);
    ensure_dataset_weighted_from(dataset, &edges, &weights)
}

/// [`ensure_dataset_weighted`] when the caller already holds the generated
/// edges + weights (saves regenerating a multi-million-edge R-MAT just to
/// hit the on-disk cache).
pub fn ensure_dataset_weighted_from(
    dataset: &Dataset,
    edges: &[crate::graph::Edge],
    weights: &[Weight],
) -> Result<DatasetDir> {
    let dir = DatasetDir::new(data_root().join(format!("{}-w.gmp", dataset.name)));
    if dir.exists() {
        return Ok(dir);
    }
    preprocess_weighted(
        dataset.name,
        edges,
        weights,
        dataset.num_vertices(),
        &dir,
        &PreprocessConfig::default(),
    )
    .with_context(|| format!("preprocessing weighted {}", dataset.name))?;
    Ok(dir)
}

/// The GraphMP configurations the paper evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphMpVariant {
    /// Compressed edge cache enabled (paper: GraphMP-C).
    Cached(Codec),
    /// Cache disabled (paper: GraphMP-NC).
    NoCache,
}

impl GraphMpVariant {
    pub fn label(&self) -> String {
        match self {
            GraphMpVariant::Cached(c) => format!("GraphMP-C({})", c.name()),
            GraphMpVariant::NoCache => "GraphMP-NC".into(),
        }
    }

    pub fn to_config(self, selective: bool, max_iters: usize) -> EngineConfig {
        let (codec, budget) = match self {
            GraphMpVariant::Cached(c) => (c, usize::MAX),
            GraphMpVariant::NoCache => (Codec::None, 0),
        };
        EngineConfig {
            max_iters,
            selective,
            cache_codec: codec,
            cache_budget: budget,
            backend: Backend::Native,
            ..Default::default()
        }
    }
}

/// Open + run one GraphMP configuration on a materialized dataset.
pub fn run_graphmp(
    dir: &DatasetDir,
    variant: GraphMpVariant,
    selective: bool,
    app: &dyn VertexProgram,
    max_iters: usize,
) -> Result<(RunResult, std::time::Duration)> {
    run_graphmp_cfg(dir, variant.to_config(selective, max_iters), app)
}

/// [`run_graphmp`] with the adaptive I/O governor switched on — the
/// "adaptive" column of the fig5/fig6/fig7 ablations.
pub fn run_graphmp_adaptive(
    dir: &DatasetDir,
    variant: GraphMpVariant,
    selective: bool,
    app: &dyn VertexProgram,
    max_iters: usize,
) -> Result<(RunResult, std::time::Duration)> {
    let mut cfg = variant.to_config(selective, max_iters);
    cfg.adaptive = true;
    run_graphmp_cfg(dir, cfg, app)
}

/// Open + run an arbitrary engine configuration on a materialized dataset.
pub fn run_graphmp_cfg(
    dir: &DatasetDir,
    cfg: EngineConfig,
    app: &dyn VertexProgram,
) -> Result<(RunResult, std::time::Duration)> {
    let engine = VswEngine::open(dir.clone(), cfg)?;
    let load = engine.load_wall;
    let result = engine.run(app)?;
    Ok((result, load))
}

/// Datasets a bench target should cover: `twitter-s` + `uk2007-s` by
/// default; all four paper datasets when `GRAPHMP_BENCH_FULL=1` (uk2014-s /
/// eu2015-s take tens of millions of edges through every baseline's disk
/// model — minutes, not seconds).
pub fn bench_datasets() -> Vec<&'static Dataset> {
    let full = std::env::var("GRAPHMP_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let names: &[&str] = if full {
        &["twitter-s", "uk2007-s", "uk2014-s", "eu2015-s"]
    } else {
        &["twitter-s", "uk2007-s"]
    };
    names.iter().map(|n| Dataset::by_name(n).unwrap()).collect()
}

/// Smaller single dataset for quick ablations (`GRAPHMP_BENCH_FULL=1` ⇒
/// uk2007-s, else twitter-s).
pub fn ablation_dataset() -> &'static Dataset {
    let full = std::env::var("GRAPHMP_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    Dataset::by_name(if full { "uk2007-s" } else { "twitter-s" }).unwrap()
}

/// One row of the Fig 8/9/10 execution-time comparison.
#[derive(Debug, Clone)]
pub struct ExecRow {
    pub system: String,
    pub dataset: &'static str,
    /// Per-iteration wall times; index 0 includes data loading (the paper's
    /// "first iteration's execution time includes the data loading time").
    pub iter_walls: Vec<std::time::Duration>,
    pub total: std::time::Duration,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub memory: u64,
}

/// Shared driver for Fig 8 (PageRank), Fig 9 (SSSP), Fig 10 (WCC) and
/// Table III: run `app` for `iters` iterations on every bench dataset with
/// GraphChi/X-Stream/GridGraph/GraphMP-NC/GraphMP-C, returning one row per
/// (system, dataset).
/// Disk bandwidth used by the exec-time figures, in MiB/s.  The paper's
/// testbed streams from a 4×HDD RAID5 (~300 MiB/s sequential); on this
/// container the page cache would serve every "disk" read at memory speed
/// and erase the I/O-bound regime the paper studies, so the figures run
/// with `storage::io`'s throttle at this rate (DESIGN.md §3).  Override
/// with `GRAPHMP_THROTTLE_MBPS` (0 disables).
pub fn figure_throttle_mbps() -> u64 {
    std::env::var("GRAPHMP_THROTTLE_MBPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300)
}

pub fn exec_time_figure(
    app: &dyn VertexProgram,
    iters: usize,
) -> Result<Vec<ExecRow>> {
    exec_time_typed(app, iters, false)
}

/// Typed/weighted generalization of [`exec_time_figure`]: runs any value
/// lane through every baseline (via `run_typed_by_name`) and both GraphMP
/// variants; `weighted` attaches the deterministic synthetic weight lane
/// to both the baselines' layouts and the VSW dataset.
pub fn exec_time_typed<V: VertexValue>(
    app: &dyn VertexProgram<V>,
    iters: usize,
    weighted: bool,
) -> Result<Vec<ExecRow>> {
    use crate::baselines;

    crate::storage::io::set_throttle(figure_throttle_mbps() << 20);
    let guard = scopeguard_throttle_off();
    let _ = &guard;

    let mut rows = Vec::new();
    for dataset in bench_datasets() {
        // generate once; both the VSW dataset materialization and the
        // baselines' layouts reuse the same edge/weight arrays
        let edges = dataset.generate();
        let weights = if weighted {
            generator::synth_weights(&edges, WEIGHT_SEED)
        } else {
            Vec::new()
        };
        let dir = if weighted {
            ensure_dataset_weighted_from(dataset, &edges, &weights)?
        } else {
            ensure_dataset(dataset)?
        };

        for sys in ["psw", "esg", "dsw"] {
            let work = std::env::temp_dir().join(format!(
                "graphmp_fig_{sys}_{}{}",
                dataset.name,
                if weighted { "_w" } else { "" }
            ));
            let t0 = std::time::Instant::now();
            let run = baselines::run_typed_by_name(
                sys,
                work,
                &edges,
                &weights,
                dataset.num_vertices(),
                app,
                iters,
            )?;
            // prepare time = everything the call spent outside the run loop
            let load = t0.elapsed().saturating_sub(run.total_wall);
            let mut walls = run.iter_walls.clone();
            if let Some(first) = walls.first_mut() {
                *first += load; // paper: first iteration includes loading
            }
            rows.push(ExecRow {
                system: baselines::display_name(sys)?.to_string(),
                dataset: dataset.name,
                total: walls.iter().sum(),
                iter_walls: walls,
                bytes_read: run.io.bytes_read,
                bytes_written: run.io.bytes_written,
                memory: run.memory_bytes,
            });
        }

        for variant in
            [GraphMpVariant::NoCache, GraphMpVariant::Cached(crate::cache::Codec::SnapLite)]
        {
            let engine = VswEngine::open(dir.clone(), variant.to_config(true, iters))?;
            let load = engine.load_wall;
            let result = engine.run(app)?;
            let mut walls: Vec<_> = result.stats.iters.iter().map(|i| i.wall).collect();
            if let Some(first) = walls.first_mut() {
                *first += load;
            }
            rows.push(ExecRow {
                system: variant.label(),
                dataset: dataset.name,
                total: walls.iter().sum(),
                iter_walls: walls,
                bytes_read: result.stats.total_bytes_read(),
                bytes_written: result.stats.total_bytes_written(),
                memory: result.stats.memory_bytes,
            });
        }
    }
    Ok(rows)
}

/// RAII guard that disables the I/O throttle when the figure run ends.
fn scopeguard_throttle_off() -> impl Drop {
    struct G;
    impl Drop for G {
        fn drop(&mut self) {
            crate::storage::io::set_throttle(0);
        }
    }
    G
}

/// Render an exec-time figure as the paper prints it: per-iteration series
/// plus the speedup-vs-GraphMP-C summary (Table III's cells).
pub fn render_exec_figure(title: &str, rows: &[ExecRow]) -> crate::util::bench::Table {
    use crate::util::humansize;
    let mut table = crate::util::bench::Table::new(
        title,
        &["dataset", "system", "total", "iter0(+load)", "steady-iter", "read", "x vs GraphMP-C"],
    );
    for dataset in rows.iter().map(|r| r.dataset).collect::<std::collections::BTreeSet<_>>() {
        let base = rows
            .iter()
            .find(|r| r.dataset == dataset && r.system.starts_with("GraphMP-C"))
            .map(|r| r.total.as_secs_f64())
            .unwrap_or(0.0);
        for r in rows.iter().filter(|r| r.dataset == dataset) {
            let steady = if r.iter_walls.len() > 1 {
                r.iter_walls[1..].iter().sum::<std::time::Duration>()
                    / (r.iter_walls.len() - 1) as u32
            } else {
                r.total
            };
            table.row(&[
                r.dataset.into(),
                r.system.clone(),
                humansize::duration(r.total),
                humansize::duration(*r.iter_walls.first().unwrap_or(&r.total)),
                humansize::duration(steady),
                humansize::bytes(r.bytes_read),
                crate::coordinator::report::ratio(base, r.total.as_secs_f64()),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::coordinator::datasets::Dataset;

    #[test]
    fn ensure_is_idempotent_and_runnable() {
        let d = Dataset::by_name("tiny").unwrap();
        let dir1 = ensure_dataset(d).unwrap();
        let dir2 = ensure_dataset(d).unwrap();
        assert_eq!(dir1.root, dir2.root);
        let (result, _load) =
            run_graphmp(&dir1, GraphMpVariant::NoCache, false, &PageRank::default(), 3).unwrap();
        assert_eq!(result.values.len(), d.num_vertices());
        assert_eq!(result.stats.num_iters(), 3);
    }

    #[test]
    fn adaptive_runner_is_bit_identical_to_fixed() {
        let d = Dataset::by_name("tiny").unwrap();
        let dir = ensure_dataset(d).unwrap();
        let app = PageRank::default();
        let (fixed, _) =
            run_graphmp(&dir, GraphMpVariant::Cached(Codec::SnapLite), true, &app, 4).unwrap();
        let (adaptive, _) =
            run_graphmp_adaptive(&dir, GraphMpVariant::Cached(Codec::SnapLite), true, &app, 4)
                .unwrap();
        assert_eq!(fixed.values, adaptive.values);
        assert!(adaptive.stats.final_prefetch_depth() >= 1);
    }

    #[test]
    fn variants_configure_cache() {
        let c = GraphMpVariant::Cached(Codec::Zlib1).to_config(true, 5);
        assert_eq!(c.cache_codec, Codec::Zlib1);
        assert!(c.cache_budget > 0);
        let nc = GraphMpVariant::NoCache.to_config(true, 5);
        assert_eq!(nc.cache_budget, 0);
    }
}
