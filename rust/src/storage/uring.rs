//! Direct-I/O shard reads behind an async submission ring.
//!
//! The cold path used to be buffered `read()` on a thread pool: every
//! shard byte crossed the page cache, and the governor's `prefetch_depth`
//! only bounded how many *files* were in flight, not how deep the device
//! queue actually ran.  [`DirectShardReader`] closes that gap:
//!
//! * shard files are opened with `O_DIRECT` (where the filesystem allows
//!   it) and read into 4 KiB-aligned buffers recycled through an
//!   [`AlignedPool`], bypassing the page cache so reads hit the device at
//!   its native block size;
//! * each file is split into 1 MiB segments driven through a kernel
//!   io_uring when available — vendored as raw syscalls, same no-network
//!   pattern as the `vendor/` shims — with a portable fallback that fans
//!   the segments out over scoped `pread` threads.  Either way the number
//!   of in-flight segments is [`DirectShardReader::queue_depth`], which
//!   the I/O governor updates every iteration, so the engine's window
//!   finally maps to real device queue depth;
//! * every degradation is *per call and bit-identical*: a kernel without
//!   io_uring, a seccomp'd container, a tmpfs that rejects `O_DIRECT`, or
//!   a short read all fall back to plain buffered reads of the same bytes
//!   (locked by `tests/direct_io.rs` and the CI `io-matrix` legs).
//!
//! Env switches: `GRAPHMP_URING=pool` forces the portable backend;
//! `GRAPHMP_URING=kernel` or unset probes the kernel ring once per
//! process with an end-to-end read-back self-test and falls back to the
//! pool if the probe fails.

use std::fs::File;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::storage::io;

/// Buffer/offset/length alignment for `O_DIRECT` (covers both 512-byte
/// and 4 KiB logical block devices).
pub const ALIGN: usize = 4096;

/// Submission granularity: one ring entry / pread per 1 MiB of file.
const SEGMENT: usize = 1 << 20;

/// Ring size per thread; clamps the effective queue depth.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
const RING_ENTRIES: u32 = 32;

/// Buffers kept alive in an [`AlignedPool`].
const POOL_MAX: usize = 16;

#[cfg(all(unix, any(target_arch = "x86_64", target_arch = "x86", target_arch = "riscv64")))]
const O_DIRECT: i32 = 0x4000;
#[cfg(all(unix, any(target_arch = "aarch64", target_arch = "arm")))]
const O_DIRECT: i32 = 0x10000;
#[cfg(all(
    unix,
    not(any(
        target_arch = "x86_64",
        target_arch = "x86",
        target_arch = "riscv64",
        target_arch = "aarch64",
        target_arch = "arm"
    ))
))]
const O_DIRECT: i32 = 0; // unknown ABI: open buffered, keep the ring

// ---------------------------------------------------------------------------
// Aligned buffers
// ---------------------------------------------------------------------------

/// A page-aligned, length-tracked byte buffer built entirely from safe
/// code: over-allocate by one alignment unit and slice from the first
/// aligned offset.  Heap allocations never move, so the offset stays
/// valid for the buffer's lifetime.
pub struct AlignedBuf {
    raw: Vec<u8>,
    off: usize,
    cap: usize,
    len: usize,
}

impl AlignedBuf {
    /// Allocate with at least `min_cap` usable bytes (rounded up to a
    /// whole number of alignment units; zero rounds up to one).
    pub fn new(min_cap: usize) -> Self {
        let cap = min_cap.div_ceil(ALIGN).max(1) * ALIGN;
        let raw = vec![0u8; cap + ALIGN];
        let off = raw.as_ptr().align_offset(ALIGN);
        debug_assert!(off < ALIGN + 1);
        Self { raw, off, cap, len: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set the visible length (must fit the capacity).  Contents up to
    /// `len` are whatever was last written there — callers fill them.
    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.cap, "len {len} exceeds capacity {}", self.cap);
        self.len = len;
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.raw[self.off..self.off + self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.raw[self.off..self.off + self.len]
    }
}

/// Free-list of [`AlignedBuf`]s so steady-state direct reads allocate
/// nothing: take the first buffer big enough, else allocate fresh.
#[derive(Default)]
pub struct AlignedPool {
    slots: Mutex<Vec<AlignedBuf>>,
}

impl AlignedPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn take(&self, min_cap: usize) -> AlignedBuf {
        let mut slots = self.slots.lock().unwrap();
        if let Some(pos) = slots.iter().position(|b| b.capacity() >= min_cap) {
            return slots.swap_remove(pos);
        }
        drop(slots);
        AlignedBuf::new(min_cap)
    }

    pub fn put(&self, mut buf: AlignedBuf) {
        buf.len = 0;
        let mut slots = self.slots.lock().unwrap();
        if slots.len() < POOL_MAX {
            slots.push(buf);
        }
    }
}

// ---------------------------------------------------------------------------
// Raw-syscall io_uring backend (Linux x86_64 / aarch64)
// ---------------------------------------------------------------------------

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    pub const IO_URING_SETUP: usize = 425;
    pub const IO_URING_ENTER: usize = 426;
    pub const MMAP: usize = 9;
    pub const MUNMAP: usize = 11;
    pub const CLOSE: usize = 3;

    /// Six-argument raw syscall.
    ///
    /// # Safety
    /// The caller must pass arguments valid for syscall `n` — pointers
    /// must reference live memory of the size the kernel expects.
    pub unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
mod sys {
    pub const IO_URING_SETUP: usize = 425;
    pub const IO_URING_ENTER: usize = 426;
    pub const MMAP: usize = 222;
    pub const MUNMAP: usize = 215;
    pub const CLOSE: usize = 57;

    /// Six-argument raw syscall.
    ///
    /// # Safety
    /// The caller must pass arguments valid for syscall `n` — pointers
    /// must reference live memory of the size the kernel expects.
    pub unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack)
        );
        ret
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod kernel {
    //! The minimal io_uring ABI subset this reader needs: setup, mmap the
    //! three ring regions, `IORING_OP_READ` submissions, `GETEVENTS`
    //! reaps.  Single-threaded by construction (one ring per I/O thread),
    //! so the submission side needs no local synchronization — only the
    //! Acquire/Release pairs the kernel shares.

    use super::sys;
    use std::sync::atomic::{AtomicU32, Ordering};

    const IORING_OP_READ: u8 = 22; // kernel >= 5.6; probe guards usage
    const IORING_ENTER_GETEVENTS: usize = 1;
    const IORING_OFF_SQ_RING: usize = 0;
    const IORING_OFF_CQ_RING: usize = 0x800_0000;
    const IORING_OFF_SQES: usize = 0x1000_0000;
    const PROT_READ_WRITE: usize = 0x3;
    const MAP_SHARED: usize = 0x1;
    const EINTR: isize = 4;

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct SqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        flags: u32,
        dropped: u32,
        array: u32,
        resv1: u32,
        resv2: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct CqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        overflow: u32,
        cqes: u32,
        flags: u32,
        resv1: u32,
        resv2: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct UringParams {
        sq_entries: u32,
        cq_entries: u32,
        flags: u32,
        sq_thread_cpu: u32,
        sq_thread_idle: u32,
        features: u32,
        wq_fd: u32,
        resv: [u32; 3],
        sq_off: SqOffsets,
        cq_off: CqOffsets,
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Sqe {
        opcode: u8,
        flags: u8,
        ioprio: u16,
        fd: i32,
        off: u64,
        addr: u64,
        len: u32,
        rw_flags: u32,
        user_data: u64,
        buf_index: u16,
        personality: u16,
        splice_fd_in: i32,
        pad: [u64; 2],
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Cqe {
        user_data: u64,
        res: i32,
        flags: u32,
    }

    /// One mmap'd kernel ring.  `!Send` by its raw pointers, which is
    /// what we want: a ring belongs to the thread that made it.
    pub struct KernelRing {
        fd: i32,
        sq_ptr: *mut u8,
        sq_len: usize,
        cq_ptr: *mut u8,
        cq_len: usize,
        sqes: *mut Sqe,
        sqes_len: usize,
        sq_entries: u32,
        sq_mask: u32,
        cq_mask: u32,
        off_sq_head: usize,
        off_sq_tail: usize,
        off_sq_array: usize,
        off_cq_head: usize,
        off_cq_tail: usize,
        off_cqes: usize,
    }

    /// mmap one ring region; negative returns in `[-4095, -1]` are
    /// `-errno`.
    unsafe fn ring_mmap(fd: i32, len: usize, offset: usize) -> Result<*mut u8, i32> {
        let r = sys::syscall6(sys::MMAP, 0, len, PROT_READ_WRITE, MAP_SHARED, fd as usize, offset);
        if (-4095..0).contains(&r) {
            Err(-r as i32)
        } else {
            Ok(r as *mut u8)
        }
    }

    unsafe fn ring_munmap(ptr: *mut u8, len: usize) {
        if !ptr.is_null() {
            sys::syscall6(sys::MUNMAP, ptr as usize, len, 0, 0, 0, 0);
        }
    }

    impl KernelRing {
        pub fn new(entries: u32) -> Result<Self, i32> {
            let mut p = UringParams::default();
            debug_assert_eq!(std::mem::size_of::<UringParams>(), 120);
            debug_assert_eq!(std::mem::size_of::<Sqe>(), 64);
            debug_assert_eq!(std::mem::size_of::<Cqe>(), 16);
            let r = unsafe {
                sys::syscall6(
                    sys::IO_URING_SETUP,
                    entries as usize,
                    std::ptr::addr_of_mut!(p) as usize,
                    0,
                    0,
                    0,
                    0,
                )
            };
            if r < 0 {
                return Err(-r as i32);
            }
            let fd = r as i32;
            let sq_len = p.sq_off.array as usize + p.sq_entries as usize * 4;
            let cq_len =
                p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<Cqe>();
            let sqes_len = p.sq_entries as usize * std::mem::size_of::<Sqe>();
            unsafe {
                let sq_ptr = match ring_mmap(fd, sq_len, IORING_OFF_SQ_RING) {
                    Ok(ptr) => ptr,
                    Err(e) => {
                        sys::syscall6(sys::CLOSE, fd as usize, 0, 0, 0, 0, 0);
                        return Err(e);
                    }
                };
                let cq_ptr = match ring_mmap(fd, cq_len, IORING_OFF_CQ_RING) {
                    Ok(ptr) => ptr,
                    Err(e) => {
                        ring_munmap(sq_ptr, sq_len);
                        sys::syscall6(sys::CLOSE, fd as usize, 0, 0, 0, 0, 0);
                        return Err(e);
                    }
                };
                let sqes = match ring_mmap(fd, sqes_len, IORING_OFF_SQES) {
                    Ok(ptr) => ptr as *mut Sqe,
                    Err(e) => {
                        ring_munmap(sq_ptr, sq_len);
                        ring_munmap(cq_ptr, cq_len);
                        sys::syscall6(sys::CLOSE, fd as usize, 0, 0, 0, 0, 0);
                        return Err(e);
                    }
                };
                let sq_mask = (sq_ptr.add(p.sq_off.ring_mask as usize) as *const u32).read();
                let cq_mask = (cq_ptr.add(p.cq_off.ring_mask as usize) as *const u32).read();
                Ok(Self {
                    fd,
                    sq_ptr,
                    sq_len,
                    cq_ptr,
                    cq_len,
                    sqes,
                    sqes_len,
                    sq_entries: p.sq_entries,
                    sq_mask,
                    cq_mask,
                    off_sq_head: p.sq_off.head as usize,
                    off_sq_tail: p.sq_off.tail as usize,
                    off_sq_array: p.sq_off.array as usize,
                    off_cq_head: p.cq_off.head as usize,
                    off_cq_tail: p.cq_off.tail as usize,
                    off_cqes: p.cq_off.cqes as usize,
                })
            }
        }

        pub fn entries(&self) -> usize {
            self.sq_entries as usize
        }

        fn sq_atomic(&self, off: usize) -> &AtomicU32 {
            unsafe { &*(self.sq_ptr.add(off) as *const AtomicU32) }
        }

        fn cq_atomic(&self, off: usize) -> &AtomicU32 {
            unsafe { &*(self.cq_ptr.add(off) as *const AtomicU32) }
        }

        /// Queue one `IORING_OP_READ`; returns false when the SQ is full.
        /// The write becomes visible to the kernel at the next
        /// [`Self::enter`].
        pub fn submit_read(
            &mut self,
            fd: i32,
            addr: u64,
            len: u32,
            off: u64,
            user_data: u64,
        ) -> bool {
            let head = self.sq_atomic(self.off_sq_head).load(Ordering::Acquire);
            let tail = self.sq_atomic(self.off_sq_tail).load(Ordering::Relaxed);
            if tail.wrapping_sub(head) >= self.sq_entries {
                return false;
            }
            let idx = (tail & self.sq_mask) as usize;
            let sqe = Sqe {
                opcode: IORING_OP_READ,
                flags: 0,
                ioprio: 0,
                fd,
                off,
                addr,
                len,
                rw_flags: 0,
                user_data,
                buf_index: 0,
                personality: 0,
                splice_fd_in: 0,
                pad: [0; 2],
            };
            unsafe {
                self.sqes.add(idx).write(sqe);
                let arr = self.sq_ptr.add(self.off_sq_array) as *mut u32;
                arr.add(idx).write(idx as u32);
            }
            self.sq_atomic(self.off_sq_tail).store(tail.wrapping_add(1), Ordering::Release);
            true
        }

        /// Submit everything queued and block for at least `min_complete`
        /// completions.  EINTR retries are safe: consumed SQEs are gone,
        /// so a retry submits only what's still queued.
        pub fn enter(&self, to_submit: u32, min_complete: u32) -> Result<(), i32> {
            loop {
                let r = unsafe {
                    sys::syscall6(
                        sys::IO_URING_ENTER,
                        self.fd as usize,
                        to_submit as usize,
                        min_complete as usize,
                        IORING_ENTER_GETEVENTS,
                        0,
                        0,
                    )
                };
                if r == -EINTR {
                    continue;
                }
                if r < 0 {
                    return Err(-r as i32);
                }
                return Ok(());
            }
        }

        /// Pop one completion: `(user_data, res)`.
        pub fn next_cqe(&mut self) -> Option<(u64, i32)> {
            let head = self.cq_atomic(self.off_cq_head).load(Ordering::Relaxed);
            let tail = self.cq_atomic(self.off_cq_tail).load(Ordering::Acquire);
            if head == tail {
                return None;
            }
            let idx = (head & self.cq_mask) as usize;
            let cqe = unsafe { (self.cq_ptr.add(self.off_cqes) as *const Cqe).add(idx).read() };
            self.cq_atomic(self.off_cq_head).store(head.wrapping_add(1), Ordering::Release);
            Some((cqe.user_data, cqe.res))
        }
    }

    impl Drop for KernelRing {
        fn drop(&mut self) {
            unsafe {
                ring_munmap(self.sq_ptr, self.sq_len);
                ring_munmap(self.cq_ptr, self.cq_len);
                ring_munmap(self.sqes as *mut u8, self.sqes_len);
                sys::syscall6(sys::CLOSE, self.fd as usize, 0, 0, 0, 0, 0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Segment readers (shared by both backends)
// ---------------------------------------------------------------------------

/// Fill `chunk` (whose file range starts at `seg_off`) up to the end of
/// the segment or the file, whichever comes first, with block-aligned
/// `pread`s.  Short reads restart from the aligned floor of the current
/// position so an `O_DIRECT` fd never sees an unaligned offset or
/// length; the few re-read bytes are the price of staying aligned.
#[cfg(unix)]
fn read_segment(file: &File, seg_off: u64, chunk: &mut [u8], file_size: u64) -> Result<()> {
    use std::os::unix::fs::FileExt;
    let want = chunk.len().min(file_size.saturating_sub(seg_off) as usize);
    let mut done = 0usize;
    while done < want {
        let floor = done & !(ALIGN - 1);
        let n = file
            .read_at(&mut chunk[floor..], seg_off + floor as u64)
            .with_context(|| format!("pread at offset {}", seg_off + floor as u64))?;
        anyhow::ensure!(n > 0, "file shrank mid-read at offset {}", seg_off + floor as u64);
        done = floor + n;
    }
    Ok(())
}

/// Portable backend: fan the file's segments out over up to `depth`
/// scoped threads of positional reads.  No persistent threads — the
/// scope joins before returning, and single-segment files read inline.
#[cfg(unix)]
fn pool_read(file: &File, size: u64, buf: &mut AlignedBuf, depth: usize) -> Result<()> {
    let slice = buf.as_mut_slice();
    let nsegs = slice.len().div_ceil(SEGMENT);
    let workers = depth.min(nsegs).min(8);
    if workers <= 1 {
        for (seg, chunk) in slice.chunks_mut(SEGMENT).enumerate() {
            read_segment(file, (seg * SEGMENT) as u64, chunk, size)?;
        }
        return Ok(());
    }
    let mut lanes: Vec<Vec<(u64, &mut [u8])>> = (0..workers).map(|_| Vec::new()).collect();
    for (seg, chunk) in slice.chunks_mut(SEGMENT).enumerate() {
        lanes[seg % workers].push(((seg * SEGMENT) as u64, chunk));
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = lanes
            .into_iter()
            .map(|lane| {
                s.spawn(move || -> Result<()> {
                    for (off, chunk) in lane {
                        read_segment(file, off, chunk, size)?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().expect("segment reader panicked")?;
        }
        Ok(())
    })
}

/// Drive one file through a kernel ring with at most `depth` segments in
/// flight.  Error completions abort (the caller falls back to a buffered
/// read); short completions — expected at EOF on `O_DIRECT` fds — are
/// finished with aligned `pread`s afterwards.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn ring_read(
    ring: &mut kernel::KernelRing,
    file: &File,
    size: u64,
    buf: &mut AlignedBuf,
    depth: usize,
) -> Result<()> {
    use std::os::unix::io::AsRawFd;
    let aligned = buf.len();
    let nsegs = aligned.div_ceil(SEGMENT);
    let base = buf.as_mut_slice().as_mut_ptr() as u64;
    let raw_fd = file.as_raw_fd();
    let depth = depth.clamp(1, ring.entries());
    let mut filled = vec![0usize; nsegs];
    let mut next = 0usize;
    let mut inflight = 0usize;
    while next < nsegs || inflight > 0 {
        let mut queued = 0u32;
        while next < nsegs && inflight < depth {
            let off = next * SEGMENT;
            let len = SEGMENT.min(aligned - off) as u32;
            if !ring.submit_read(raw_fd, base + off as u64, len, off as u64, next as u64) {
                break;
            }
            next += 1;
            inflight += 1;
            queued += 1;
        }
        ring.enter(queued, 1)
            .map_err(|e| anyhow::anyhow!("io_uring_enter failed (errno {e})"))?;
        while let Some((user_data, res)) = ring.next_cqe() {
            inflight -= 1;
            anyhow::ensure!(res >= 0, "ring read failed (errno {})", -res);
            filled[user_data as usize] = res as usize;
        }
    }
    let slice = buf.as_mut_slice();
    for (seg, chunk) in slice.chunks_mut(SEGMENT).enumerate() {
        let off = (seg * SEGMENT) as u64;
        let want = chunk.len().min(size.saturating_sub(off) as usize);
        if filled[seg] < want {
            read_segment(file, off, chunk, size)?;
        }
    }
    Ok(())
}

/// Read through this thread's lazily-created ring.  `Ok(false)` means the
/// thread has no usable ring (creation failed once; remembered) and the
/// caller should take the pool path.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn ring_read_local(file: &File, size: u64, buf: &mut AlignedBuf, depth: usize) -> Result<bool> {
    use std::cell::RefCell;
    thread_local! {
        static RING: RefCell<Option<Option<kernel::KernelRing>>> = const { RefCell::new(None) };
    }
    RING.with(|cell| {
        let mut slot = cell.borrow_mut();
        let entry = slot.get_or_insert_with(|| kernel::KernelRing::new(RING_ENTRIES).ok());
        match entry.as_mut() {
            Some(ring) => ring_read(ring, file, size, buf, depth).map(|()| true),
            None => Ok(false),
        }
    })
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn ring_read_local(_file: &File, _size: u64, _buf: &mut AlignedBuf, _depth: usize) -> Result<bool> {
    Ok(false)
}

/// One-shot self-test: write a pattern file, read it back through a fresh
/// ring, compare bytes.  Anything short of a bit-exact round trip (no
/// syscall, seccomp denial, unsupported opcode) reports the kernel
/// backend unavailable.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn probe_kernel_ring() -> bool {
    fn run() -> Result<bool> {
        let len = 2 * ALIGN + 123;
        let path = std::env::temp_dir().join(format!("gmp_uring_probe_{}", std::process::id()));
        let pattern: Vec<u8> = (0..len).map(|i| (i * 7 % 251) as u8).collect();
        std::fs::write(&path, &pattern)?;
        let mut ring = match kernel::KernelRing::new(8) {
            Ok(r) => r,
            Err(_) => {
                let _ = std::fs::remove_file(&path);
                return Ok(false);
            }
        };
        let file = File::open(&path)?;
        let mut buf = AlignedBuf::new(len);
        buf.set_len(len.div_ceil(ALIGN) * ALIGN);
        let ok = ring_read(&mut ring, &file, len as u64, &mut buf, 4).is_ok()
            && &buf.as_slice()[..len] == pattern.as_slice();
        let _ = std::fs::remove_file(&path);
        Ok(ok)
    }
    run().unwrap_or(false)
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn probe_kernel_ring() -> bool {
    false
}

// ---------------------------------------------------------------------------
// DirectShardReader
// ---------------------------------------------------------------------------

/// Which submission backend a reader drives.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RingMode {
    /// mmap'd io_uring, one ring per I/O thread.
    Kernel,
    /// Scoped-thread positional reads (portable; also the probe-failed
    /// fallback).
    Pool,
}

impl RingMode {
    pub fn name(self) -> &'static str {
        match self {
            RingMode::Kernel => "kernel",
            RingMode::Pool => "pool",
        }
    }
}

static KERNEL_OK: OnceLock<bool> = OnceLock::new();

fn kernel_available() -> bool {
    *KERNEL_OK.get_or_init(probe_kernel_ring)
}

/// `GRAPHMP_URING` env + probe → the backend a new reader uses.
pub fn resolve_mode() -> RingMode {
    match std::env::var("GRAPHMP_URING").ok().as_deref() {
        Some("pool") => RingMode::Pool,
        // "kernel", "auto", unset, or anything else: probe decides
        _ => {
            if kernel_available() {
                RingMode::Kernel
            } else {
                RingMode::Pool
            }
        }
    }
}

/// Whole-shard reads with `O_DIRECT` + aligned buffers + a submission
/// backend, byte-for-byte equivalent to [`io::read_file`] (the engine's
/// `--direct-io` flag swaps this in for every shard read).  Thread-safe:
/// any I/O-pool worker may call [`Self::read_file`] concurrently.
pub struct DirectShardReader {
    depth: AtomicUsize,
    pool: AlignedPool,
    mode: RingMode,
    direct_reads: AtomicU64,
    fallback_reads: AtomicU64,
}

impl DirectShardReader {
    /// Backend chosen by [`resolve_mode`] (env + probe).
    pub fn new(depth: usize) -> Arc<Self> {
        Arc::new(Self::with_mode(resolve_mode(), depth))
    }

    /// Force a backend (tests exercise both without touching the
    /// process-global env).
    pub fn with_mode(mode: RingMode, depth: usize) -> Self {
        Self {
            depth: AtomicUsize::new(depth.max(1)),
            pool: AlignedPool::new(),
            mode,
            direct_reads: AtomicU64::new(0),
            fallback_reads: AtomicU64::new(0),
        }
    }

    pub fn mode(&self) -> RingMode {
        self.mode
    }

    /// The governor feeds its per-iteration window here, so the planned
    /// prefetch window *is* the device queue depth.
    pub fn set_queue_depth(&self, depth: usize) {
        self.depth.store(depth.max(1), Ordering::Relaxed);
    }

    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// `(direct, fallback)` read counts since construction.
    pub fn counts(&self) -> (u64, u64) {
        (self.direct_reads.load(Ordering::Relaxed), self.fallback_reads.load(Ordering::Relaxed))
    }

    /// Read a whole file.  Any direct-path failure degrades to a plain
    /// buffered read of the same bytes; both paths hit the global I/O
    /// counters and throttle exactly once.
    pub fn read_file(&self, path: &Path) -> Result<Vec<u8>> {
        let t0 = Instant::now();
        let out = match self.read_direct(path) {
            Ok(v) => {
                self.direct_reads.fetch_add(1, Ordering::Relaxed);
                v
            }
            Err(_) => {
                self.fallback_reads.fetch_add(1, Ordering::Relaxed);
                std::fs::read(path).with_context(|| format!("open {}", path.display()))?
            }
        };
        io::account_read(out.len() as u64, t0.elapsed());
        Ok(out)
    }

    #[cfg(unix)]
    fn read_direct(&self, path: &Path) -> Result<Vec<u8>> {
        let file = open_direct(path)?;
        let size = file.metadata()?.len();
        if size == 0 {
            return Ok(Vec::new());
        }
        let aligned = (size as usize).div_ceil(ALIGN) * ALIGN;
        let mut buf = self.pool.take(aligned);
        buf.set_len(aligned);
        let depth = self.depth.load(Ordering::Relaxed).max(1);
        let mut done = false;
        if self.mode == RingMode::Kernel {
            done = ring_read_local(&file, size, &mut buf, depth)?;
        }
        if !done {
            pool_read(&file, size, &mut buf, depth)?;
        }
        let out = buf.as_slice()[..size as usize].to_vec();
        self.pool.put(buf);
        Ok(out)
    }

    #[cfg(not(unix))]
    fn read_direct(&self, path: &Path) -> Result<Vec<u8>> {
        // no positional-read trait in scope portably; the buffered
        // fallback in read_file carries the contract
        anyhow::bail!("direct I/O unavailable on this platform ({})", path.display())
    }
}

/// Open for reading with `O_DIRECT` where the filesystem accepts it.
/// tmpfs (CI work dirs, /tmp) rejects it with EINVAL — the buffered fd
/// reads identical bytes, only the cache behavior differs.
#[cfg(unix)]
fn open_direct(path: &Path) -> Result<File> {
    use std::os::unix::fs::OpenOptionsExt;
    if O_DIRECT != 0 {
        if let Ok(f) = std::fs::OpenOptions::new().read(true).custom_flags(O_DIRECT).open(path) {
            return Ok(f);
        }
    }
    File::open(path).with_context(|| format!("open {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gmp_uring_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn aligned_buf_is_aligned_and_pool_recycles() {
        for cap in [0usize, 1, ALIGN - 1, ALIGN, ALIGN + 1, 3 * ALIGN + 7] {
            let mut b = AlignedBuf::new(cap);
            assert_eq!(b.capacity() % ALIGN, 0);
            assert!(b.capacity() >= cap.max(1));
            b.set_len(b.capacity());
            assert_eq!(b.as_slice().as_ptr() as usize % ALIGN, 0, "cap {cap}");
            assert_eq!(b.as_mut_slice().len(), b.capacity());
        }
        let pool = AlignedPool::new();
        let b = pool.take(ALIGN);
        let ptr = b.as_slice().as_ptr() as usize;
        pool.put(b);
        let b2 = pool.take(ALIGN);
        assert_eq!(b2.as_slice().as_ptr() as usize, ptr, "pool must recycle the buffer");
        assert!(b2.is_empty(), "recycled buffers come back length-reset");
        // asking for more than the recycled capacity allocates fresh
        pool.put(b2);
        let big = pool.take(64 * ALIGN);
        assert!(big.capacity() >= 64 * ALIGN);
    }

    #[test]
    fn queue_depth_clamps_to_one() {
        let r = DirectShardReader::with_mode(RingMode::Pool, 4);
        r.set_queue_depth(0);
        assert_eq!(r.queue_depth(), 1);
        r.set_queue_depth(9);
        assert_eq!(r.queue_depth(), 9);
    }

    #[test]
    fn reader_matches_buffered_read_in_both_modes() {
        let sizes = [
            0usize,
            1,
            511,
            4095,
            4096,
            4097,
            SEGMENT - 1,
            SEGMENT,
            SEGMENT + 1,
            2 * SEGMENT + ALIGN - 1,
        ];
        for (i, &size) in sizes.iter().enumerate() {
            let p = tmp(&format!("match_{i}.bin"));
            let data: Vec<u8> = (0..size).map(|j| (j * 31 % 253) as u8).collect();
            std::fs::write(&p, &data).unwrap();
            for mode in [RingMode::Pool, RingMode::Kernel] {
                let reader = DirectShardReader::with_mode(mode, 4);
                let got = reader.read_file(&p).unwrap();
                assert_eq!(got, data, "mode {mode:?} size {size}");
            }
            let _ = std::fs::remove_file(&p);
        }
    }

    #[test]
    fn reader_accounts_io_and_errors_on_missing_file() {
        let p = tmp("acct.bin");
        std::fs::write(&p, vec![7u8; 10_000]).unwrap();
        let reader = DirectShardReader::with_mode(resolve_mode(), 2);
        let before = io::snapshot();
        let got = reader.read_file(&p).unwrap();
        assert_eq!(got.len(), 10_000);
        let delta = io::snapshot().since(&before);
        assert!(delta.bytes_read >= 10_000, "direct reads must hit the global counters");
        assert!(delta.read_ops >= 1);
        assert!(reader.read_file(&tmp("definitely_missing.bin")).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
