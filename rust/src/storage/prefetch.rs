//! Pipelined shard I/O primitives (journal version §"overlapping I/O with
//! computation", arXiv:1810.04334).
//!
//! The VSW engine's steady state is `load shard → update vertices`,
//! repeated P times per iteration.  Loading synchronously on the compute
//! path serializes disk + decompression behind the update kernels; these
//! primitives let a small I/O stage run *ahead* of compute with a bounded
//! in-flight budget, so the semi-external memory envelope still holds
//! (never more than `depth` decoded shards beyond the ones being
//! processed):
//!
//! * [`Semaphore`] — the in-flight budget gate shared by the engine's
//!   producer (I/O pool) and consumers (compute pool);
//! * [`ReadAhead`] — ordered background file read-ahead for strictly
//!   sequential consumers (the engine's cache-warming load phase and the
//!   PSW/ESG/DSW/VSP baselines' per-iteration streams).
//!
//! The engine-side orchestration (bloom screening + cache probe + decode on
//! the I/O pool, completion channel into the compute pool) lives in
//! `engine::vsw`; everything here is engine-agnostic.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::Result;

use crate::storage::io;
use crate::storage::uring::DirectShardReader;

/// A counting semaphore (no std equivalent in the offline crate set).
///
/// Gates how many prefetched shards may exist between "read off disk" and
/// "consumed by a compute worker".
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Self { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    /// Block until a permit is available, then take it.
    pub fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    /// Take a permit if one is free right now; never blocks.  The adaptive
    /// governor's cache-resident fast path uses this: a shard the cache can
    /// serve should not wait behind (or consume) a read-ahead slot.
    pub fn try_acquire(&self) -> bool {
        let mut p = self.permits.lock().unwrap();
        if *p == 0 {
            false
        } else {
            *p -= 1;
            true
        }
    }

    /// Return a permit.
    pub fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        self.cv.notify_one();
    }
}

enum Inner {
    /// depth 0: plain synchronous reads (no thread, no reordering risk).
    Sync(VecDeque<PathBuf>, Option<Arc<DirectShardReader>>),
    /// background reader feeding a bounded channel.
    Async {
        rx: Option<mpsc::Receiver<Result<Vec<u8>>>>,
        handle: Option<thread::JoinHandle<()>>,
    },
}

fn read_via(reader: &Option<Arc<DirectShardReader>>, path: &std::path::Path) -> Result<Vec<u8>> {
    match reader {
        Some(r) => r.read_file(path),
        None => io::read_file(path),
    }
}

/// Ordered file read-ahead: yields each path's contents **in the order
/// given**, reading up to `depth` files ahead of the consumer on a
/// background thread.  All reads go through [`io::read_file`], so the
/// global I/O accounting (and the HDD throttle) still applies.
///
/// Memory bound: at most `depth` buffered files + 1 in the reader's hand.
pub struct ReadAhead {
    inner: Inner,
}

impl ReadAhead {
    pub fn new(paths: Vec<PathBuf>, depth: usize) -> Self {
        Self::with_reader(paths, depth, None)
    }

    /// Like [`ReadAhead::new`], but routing every read through a
    /// [`DirectShardReader`] when one is given (`--direct-io`): the
    /// cache-warming load phase then does `O_DIRECT` ring reads instead
    /// of buffered ones, with identical bytes and accounting.
    pub fn with_reader(
        paths: Vec<PathBuf>,
        depth: usize,
        reader: Option<Arc<DirectShardReader>>,
    ) -> Self {
        if depth == 0 {
            return Self { inner: Inner::Sync(paths.into(), reader) };
        }
        let (tx, rx) = mpsc::sync_channel::<Result<Vec<u8>>>(depth);
        let handle = thread::spawn(move || {
            for path in paths {
                let item = read_via(&reader, &path);
                if tx.send(item).is_err() {
                    return; // consumer dropped the iterator; stop reading
                }
            }
        });
        Self { inner: Inner::Async { rx: Some(rx), handle: Some(handle) } }
    }
}

impl Iterator for ReadAhead {
    type Item = Result<Vec<u8>>;

    fn next(&mut self) -> Option<Self::Item> {
        match &mut self.inner {
            Inner::Sync(paths, reader) => paths.pop_front().map(|p| read_via(reader, &p)),
            Inner::Async { rx, .. } => rx.as_ref()?.recv().ok(),
        }
    }
}

impl Drop for ReadAhead {
    fn drop(&mut self) {
        if let Inner::Async { rx, handle } = &mut self.inner {
            drop(rx.take()); // unblocks the reader's send
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn write_fixtures(tag: &str, n: usize) -> Vec<PathBuf> {
        let dir = std::env::temp_dir().join(format!("gmp_pf_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (0..n)
            .map(|i| {
                let p = dir.join(format!("f{i}.bin"));
                std::fs::write(&p, vec![i as u8; 100 + i]).unwrap();
                p
            })
            .collect()
    }

    #[test]
    fn readahead_preserves_order() {
        for depth in [0usize, 1, 3, 16] {
            let paths = write_fixtures(&format!("ord{depth}"), 8);
            let got: Vec<Vec<u8>> =
                ReadAhead::new(paths, depth).map(|r| r.unwrap()).collect();
            assert_eq!(got.len(), 8);
            for (i, buf) in got.iter().enumerate() {
                assert_eq!(buf.len(), 100 + i, "depth {depth} file {i}");
                assert!(buf.iter().all(|&b| b == i as u8));
            }
        }
    }

    #[test]
    fn readahead_surfaces_missing_file() {
        let mut paths = write_fixtures("miss", 2);
        paths.insert(1, PathBuf::from("/definitely/not/there.bin"));
        let results: Vec<_> = ReadAhead::new(paths, 2).collect();
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok(), "reader must continue past a failed file");
    }

    #[test]
    fn readahead_accounts_bytes() {
        let paths = write_fixtures("acct", 4);
        let want: u64 = (0..4).map(|i| 100 + i as u64).sum();
        let before = io::snapshot();
        let n: usize = ReadAhead::new(paths, 2).map(|r| r.unwrap().len()).sum();
        assert_eq!(n as u64, want);
        assert!(io::snapshot().since(&before).bytes_read >= want);
    }

    #[test]
    fn readahead_with_direct_reader_matches_buffered() {
        use crate::storage::uring::{DirectShardReader, RingMode};
        let paths = write_fixtures("direct", 6);
        let want: Vec<Vec<u8>> = paths.iter().map(|p| std::fs::read(p).unwrap()).collect();
        for depth in [0usize, 2] {
            let reader = Arc::new(DirectShardReader::with_mode(RingMode::Pool, 2));
            let got: Vec<Vec<u8>> = ReadAhead::with_reader(paths.clone(), depth, Some(reader))
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(got, want, "depth {depth}");
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let paths = write_fixtures("drop", 16);
        let mut ra = ReadAhead::new(paths, 2);
        assert!(ra.next().unwrap().is_ok());
        drop(ra); // must join the reader without deadlock
    }

    #[test]
    fn semaphore_bounds_concurrency() {
        let sem = Arc::new(Semaphore::new(3));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..16 {
            let (sem, inside, peak) = (sem.clone(), inside.clone(), peak.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    sem.acquire();
                    let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    inside.fetch_sub(1, Ordering::SeqCst);
                    sem.release();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 3, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn try_acquire_never_blocks_and_respects_budget() {
        let sem = Semaphore::new(2);
        assert!(sem.try_acquire());
        assert!(sem.try_acquire());
        assert!(!sem.try_acquire(), "no permits left");
        sem.release();
        assert!(sem.try_acquire());
        sem.release();
        sem.release();
        // blocking acquire still works after try_acquire traffic
        sem.acquire();
        sem.release();
    }
}
