//! On-disk formats + instrumented I/O.
//!
//! Everything GraphMP persists lives in a `<name>.gmp/` directory (DESIGN.md
//! §6): a JSON property file, a binary vertex-info file, one `.gms` CSR
//! shard per interval and one `.gmb` Bloom filter per shard.  All binary
//! files are framed by [`format`]'s chunk container (magic + version +
//! length + CRC32) so corruption and truncation fail loudly.
//!
//! [`io`] wraps reads/writes with global byte counters — the measured side
//! of the paper's Table II analysis — and an optional throttle that models
//! HDD bandwidth so that disk-era cost ratios are reproducible on a
//! container whose page cache would otherwise hide them.

pub mod delta;
pub mod durable;
pub mod format;
pub mod io;
pub mod prefetch;
pub mod property;
pub mod shardfile;
pub mod uring;
pub mod vertexinfo;

use std::path::{Path, PathBuf};

/// Layout of a preprocessed dataset directory.
#[derive(Debug, Clone)]
pub struct DatasetDir {
    pub root: PathBuf,
}

impl DatasetDir {
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    pub fn property_path(&self) -> PathBuf {
        self.root.join("property.json")
    }

    pub fn vertexinfo_path(&self) -> PathBuf {
        self.root.join("vertexinfo.bin")
    }

    pub fn shard_path(&self, i: usize) -> PathBuf {
        self.root.join(format!("shard_{i:04}.gms"))
    }

    pub fn bloom_path(&self, i: usize) -> PathBuf {
        self.root.join(format!("bloom_{i:04}.gmb"))
    }

    // -- dynamic-graph (epoch) artifacts ---------------------------------

    /// The epoch manifest (`runtime::EpochManifest`); absent on a dataset
    /// that has never been mutated.
    pub fn epochs_path(&self) -> PathBuf {
        self.root.join("epochs.json")
    }

    /// Shard `i`'s cumulative delta state as of epoch `e`.
    pub fn delta_path(&self, i: usize, e: u64) -> PathBuf {
        self.root.join(format!("delta_{i:04}_e{e:04}.gmd"))
    }

    /// Shard `i`'s Bloom filter rebuilt at epoch `e`.
    pub fn epoch_bloom_path(&self, i: usize, e: u64) -> PathBuf {
        self.root.join(format!("bloom_{i:04}_e{e:04}.gmb"))
    }

    /// Shard `i`'s merged (compacted) base file written at epoch `e`.
    pub fn epoch_shard_path(&self, i: usize, e: u64) -> PathBuf {
        self.root.join(format!("shard_{i:04}_e{e:04}.gms"))
    }

    /// Degree arrays as of epoch `e`.
    pub fn epoch_vertexinfo_path(&self, e: u64) -> PathBuf {
        self.root.join(format!("vertexinfo_e{e:04}.bin"))
    }

    /// The archived mutation log epoch `e` applied.
    pub fn batch_path(&self, e: u64) -> PathBuf {
        self.root.join(format!("batch_e{e:04}.gmdl"))
    }

    /// Saved fixpoint values of `app` (for incremental restart).
    pub fn values_path(&self, app: &str) -> PathBuf {
        self.root.join(format!("values_{app}.gmv"))
    }

    /// Standing-query state of `app` (`graphmp watch` — baseline values,
    /// last changed-set, sliding-window membership).
    pub fn watch_path(&self, app: &str) -> PathBuf {
        self.root.join(format!("watch_{app}.gmw"))
    }

    pub fn exists(&self) -> bool {
        self.property_path().exists()
    }

    pub fn create(&self) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.root)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_stable() {
        let d = DatasetDir::new("/tmp/x.gmp");
        assert!(d.shard_path(3).ends_with("shard_0003.gms"));
        assert!(d.bloom_path(12).ends_with("bloom_0012.gmb"));
        assert!(d.property_path().ends_with("property.json"));
        assert!(d.epochs_path().ends_with("epochs.json"));
        assert!(d.delta_path(3, 2).ends_with("delta_0003_e0002.gmd"));
        assert!(d.epoch_bloom_path(1, 2).ends_with("bloom_0001_e0002.gmb"));
        assert!(d.epoch_shard_path(0, 5).ends_with("shard_0000_e0005.gms"));
        assert!(d.epoch_vertexinfo_path(9).ends_with("vertexinfo_e0009.bin"));
        assert!(d.batch_path(4).ends_with("batch_e0004.gmdl"));
        assert!(d.values_path("wcc").ends_with("values_wcc.gmv"));
        assert!(d.watch_path("spmv").ends_with("watch_spmv.gmw"));
    }
}
