//! Delta storage for dynamic graphs: the `GMDL` mutation log and the
//! `GMDS` per-interval delta shard.
//!
//! GraphMP's preprocessing writes base shards once; this module is what
//! lets a dataset absorb edge insertions/deletions afterwards without a
//! full rebuild.  Two on-disk artifacts:
//!
//! * **`GMDL` mutation log** — a batch of ordered edge mutations (insert
//!   with optional weight, delete with tombstone semantics).  `graphmp
//!   ingest` consumes one and archives it per epoch so incremental restart
//!   can replay "what changed since".  A 3/4-column text form (`+ s d [w]`
//!   / `- s d`) is accepted too.
//! * **`GMDS` delta shard** — the cumulative mutation state of one vertex
//!   interval relative to its base shard file: inserted edges grouped by
//!   destination (insertion order preserved within a row) plus a tombstone
//!   set that kills base edges.  Readers merge base rows with the resident
//!   delta inside the gather fold (`engine::backend::DeltaRows`), in
//!   exactly the row order a from-scratch preprocess of the final edge
//!   list would produce — which is what makes delta-merged execution
//!   bit-identical to a rebuild.
//!
//! Both are framed binary (magic + version + length + CRC32), like every
//! other GraphMP file.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::csr::Csr;
use crate::graph::mutation::Mutation;
use crate::graph::{VertexId, Weight};
use crate::storage::format::{
    frame, get_f32s, get_u32, get_u32s, get_u64, get_u64s, put_f32s, put_u32, put_u32s, put_u64,
    put_u64s, unframe,
};
use crate::storage::io;

const LOG_MAGIC: &[u8; 4] = b"GMDL";
const LOG_VERSION: u32 = 1;

const SHARD_MAGIC: &[u8; 4] = b"GMDS";
const SHARD_VERSION: u32 = 1;

const VALUES_MAGIC: &[u8; 4] = b"GMVV";
const VALUES_VERSION: u32 = 1;

const WATCH_MAGIC: &[u8; 4] = b"GMCS";
const WATCH_VERSION: u32 = 1;

// ---- GMDL mutation log ------------------------------------------------------

/// Serialize a mutation batch to framed `GMDL` bytes.
pub fn log_to_bytes(batch: &[Mutation]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(8 + batch.len() * 13);
    put_u64(&mut payload, batch.len() as u64);
    for m in batch {
        match *m {
            Mutation::Insert { src, dst, weight } => {
                payload.push(0);
                put_u32(&mut payload, src);
                put_u32(&mut payload, dst);
                payload.extend_from_slice(&weight.to_le_bytes());
            }
            Mutation::Delete { src, dst } => {
                payload.push(1);
                put_u32(&mut payload, src);
                put_u32(&mut payload, dst);
                payload.extend_from_slice(&1.0f32.to_le_bytes());
            }
        }
    }
    frame(LOG_MAGIC, LOG_VERSION, &payload)
}

/// Parse a framed `GMDL` buffer.
pub fn log_from_bytes(buf: &[u8]) -> Result<Vec<Mutation>> {
    let (version, payload) = unframe(LOG_MAGIC, buf)?;
    anyhow::ensure!(version == LOG_VERSION, "mutation log version {version}");
    let (n, mut p) = get_u64(payload, 0)?;
    let n = n as usize;
    // checked arithmetic: a crafted record count must parse-error, not
    // wrap the length check and walk past the buffer
    anyhow::ensure!(
        n.checked_mul(13).and_then(|b| b.checked_add(8)) == Some(payload.len()),
        "mutation log length mismatch ({} records declared)",
        n
    );
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let op = payload[p];
        p += 1;
        let (src, q) = get_u32(payload, p)?;
        let (dst, q) = get_u32(payload, q)?;
        let weight = f32::from_le_bytes(payload[q..q + 4].try_into().unwrap());
        p = q + 4;
        out.push(match op {
            0 => Mutation::Insert { src, dst, weight },
            1 => Mutation::Delete { src, dst },
            other => bail!("mutation log: unknown op {other}"),
        });
    }
    anyhow::ensure!(p == payload.len(), "mutation log trailing bytes");
    Ok(out)
}

/// Write a mutation batch through the accounting layer.
pub fn save_log(batch: &[Mutation], path: &Path) -> Result<()> {
    io::write_file(path, &log_to_bytes(batch))
}

/// Read a binary mutation log.
pub fn load_log(path: &Path) -> Result<Vec<Mutation>> {
    log_from_bytes(&io::read_file(path)?)
}

/// Parse the text mutation form: one mutation per line, `+ src dst
/// [weight]` inserts (weight defaults to 1) and `- src dst` deletes;
/// `#`/`%` comments and blank lines are skipped.
pub fn log_from_text(text: &str) -> Result<Vec<Mutation>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(op), Some(a), Some(b)) = (it.next(), it.next(), it.next()) else {
            bail!("line {}: expected `+|- src dst [weight]`", lineno + 1);
        };
        let src: VertexId = a.parse().with_context(|| format!("line {}: src", lineno + 1))?;
        let dst: VertexId = b.parse().with_context(|| format!("line {}: dst", lineno + 1))?;
        match op {
            "+" => {
                let weight: Weight = match it.next() {
                    Some(w) => {
                        w.parse().with_context(|| format!("line {}: weight", lineno + 1))?
                    }
                    None => 1.0,
                };
                out.push(Mutation::Insert { src, dst, weight });
            }
            "-" => out.push(Mutation::Delete { src, dst }),
            other => bail!("line {}: unknown op {other:?} (want + or -)", lineno + 1),
        }
    }
    Ok(out)
}

/// Read a mutation batch, auto-detecting the binary (`GMDL` magic) or text
/// form.
pub fn load_log_auto(path: &Path) -> Result<Vec<Mutation>> {
    let bytes = io::read_file(path)?;
    if bytes.len() >= 4 && &bytes[0..4] == LOG_MAGIC {
        log_from_bytes(&bytes)
    } else {
        // re-read as text to keep line numbers in errors
        let r = BufReader::new(File::open(path)?);
        let mut text = String::new();
        for line in r.lines() {
            text.push_str(&line?);
            text.push('\n');
        }
        log_from_text(&text)
    }
}

// ---- GMVV saved fixpoint values ---------------------------------------------

/// Persist a run's fixpoint values tagged with the epoch they were computed
/// at — the warm-start input of incremental restart.
pub fn save_values(path: &Path, epoch: u64, values: &crate::graph::AnyValues) -> Result<()> {
    use crate::storage::format::put_any_values;
    let mut payload = Vec::new();
    put_u64(&mut payload, epoch);
    put_any_values(&mut payload, values);
    io::write_file(path, &frame(VALUES_MAGIC, VALUES_VERSION, &payload))
}

/// Load saved fixpoint values; returns `(epoch, values)`.
pub fn load_values(path: &Path) -> Result<(u64, crate::graph::AnyValues)> {
    use crate::storage::format::get_any_values;
    let buf = io::read_file(path)?;
    let (version, payload) = unframe(VALUES_MAGIC, &buf)?;
    anyhow::ensure!(version == VALUES_VERSION, "saved values version {version}");
    let (epoch, p) = get_u64(payload, 0)?;
    let (values, p) = get_any_values(payload, p)?;
    anyhow::ensure!(p == payload.len(), "saved values trailing bytes");
    Ok((epoch, values))
}

// ---- GMCS standing-query (watch) sidecar ------------------------------------

/// Persistent state of one standing query (`graphmp watch`), stored next
/// to the GMVV fixpoint: the epoch the query last emitted at, the baseline
/// values to diff the next epoch against, the changed-set of the most
/// recent emission, and (for `--window N`) which payload ingest epochs are
/// currently inside the sliding window.
#[derive(Debug, Clone, PartialEq)]
pub struct WatchState {
    /// Epoch the `values` baseline was computed at.
    pub epoch: u64,
    /// Count-window size in ingest batches; 0 = unbounded (no expiry).
    pub window: u32,
    /// Ingest epochs currently inside the window, oldest first.  Expiry
    /// epochs the watch itself created are never listed here.
    pub window_epochs: Vec<u64>,
    /// Vertices re-emitted by the most recent advance (the changed-set).
    pub last_changed: Vec<VertexId>,
    /// Full baseline values at `epoch` — what the next advance diffs
    /// against, bit for bit.
    pub values: crate::graph::AnyValues,
}

/// Persist a standing query's state (`GMCS`).
pub fn save_watch(path: &Path, state: &WatchState) -> Result<()> {
    use crate::storage::format::put_any_values;
    let mut payload = Vec::new();
    put_u64(&mut payload, state.epoch);
    put_u32(&mut payload, state.window);
    put_u64s(&mut payload, &state.window_epochs);
    put_u32s(&mut payload, &state.last_changed);
    put_any_values(&mut payload, &state.values);
    io::write_file(path, &frame(WATCH_MAGIC, WATCH_VERSION, &payload))
}

/// Load a standing query's state (`GMCS`).
pub fn load_watch(path: &Path) -> Result<WatchState> {
    use crate::storage::format::get_any_values;
    let buf = io::read_file(path)?;
    let (version, payload) = unframe(WATCH_MAGIC, &buf)?;
    anyhow::ensure!(version == WATCH_VERSION, "watch state version {version}");
    let (epoch, p) = get_u64(payload, 0)?;
    let (window, p) = get_u32(payload, p)?;
    let (window_epochs, p) = get_u64s(payload, p)?;
    let (last_changed, p) = get_u32s(payload, p)?;
    let (values, p) = get_any_values(payload, p)?;
    anyhow::ensure!(p == payload.len(), "watch state trailing bytes");
    Ok(WatchState { epoch, window, window_epochs, last_changed, values })
}

// ---- GMDS delta shard -------------------------------------------------------

/// Cumulative mutation state of one vertex interval `[lo, hi)` relative to
/// its base shard file.
///
/// * `ins_*` — inserted edges as a mini-CSR grouped by destination, with
///   insertion order preserved inside each row (exactly the order a
///   from-scratch preprocess would append them in).  `ins_wgt` is empty
///   when every insert is unit-weight *and* the base shard is unweighted.
/// * `tomb_*` — per-row **sorted** source ids whose base edges are dead: a
///   tombstone `(s, d)` kills every base edge `(s, d)`, never an insert
///   (deletes prune the insert list directly at ingest time).
/// * `dropped_base` — how many base edges the tombstones kill, recorded at
///   ingest time so readers can report effective edge counts without
///   rescanning the base shard.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeltaShard {
    pub lo: VertexId,
    pub hi: VertexId,
    pub ins_row_ptr: Vec<u32>,
    pub ins_col: Vec<VertexId>,
    /// Parallel to `ins_col`; empty = all unit weights.
    pub ins_wgt: Vec<Weight>,
    pub tomb_row_ptr: Vec<u32>,
    /// Sorted (ascending, deduplicated) within each row.
    pub tomb_src: Vec<VertexId>,
    pub dropped_base: u64,
}

impl DeltaShard {
    /// Build from per-row insert/tombstone lists (ingest's working form).
    /// `tomb_rows` entries need not be sorted; they are normalized here.
    pub fn from_rows(
        lo: VertexId,
        hi: VertexId,
        ins_rows: &[Vec<(VertexId, Weight)>],
        tomb_rows: &[Vec<VertexId>],
        dropped_base: u64,
        keep_weights: bool,
    ) -> Self {
        let rows = (hi - lo) as usize;
        assert_eq!(ins_rows.len(), rows);
        assert_eq!(tomb_rows.len(), rows);
        let mut d = DeltaShard {
            lo,
            hi,
            ins_row_ptr: Vec::with_capacity(rows + 1),
            ins_col: Vec::new(),
            ins_wgt: Vec::new(),
            tomb_row_ptr: Vec::with_capacity(rows + 1),
            tomb_src: Vec::new(),
            dropped_base,
        };
        d.ins_row_ptr.push(0);
        d.tomb_row_ptr.push(0);
        for r in 0..rows {
            for &(s, w) in &ins_rows[r] {
                d.ins_col.push(s);
                if keep_weights {
                    d.ins_wgt.push(w);
                }
            }
            d.ins_row_ptr.push(d.ins_col.len() as u32);
            let mut t = tomb_rows[r].clone();
            t.sort_unstable();
            t.dedup();
            d.tomb_src.extend_from_slice(&t);
            d.tomb_row_ptr.push(d.tomb_src.len() as u32);
        }
        d
    }

    pub fn num_rows(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    /// Total inserted edges resident in this delta.
    pub fn ins_count(&self) -> usize {
        self.ins_col.len()
    }

    pub fn num_tombstones(&self) -> usize {
        self.tomb_src.len()
    }

    pub fn is_weighted(&self) -> bool {
        !self.ins_wgt.is_empty()
    }

    /// Is the delta a no-op (possible after insert-then-delete sequences)?
    pub fn is_empty(&self) -> bool {
        self.ins_col.is_empty() && self.tomb_src.is_empty()
    }

    /// Inserted sources of local row `r`, in insertion order.
    #[inline]
    pub fn ins_sources(&self, r: usize) -> &[VertexId] {
        &self.ins_col[self.ins_row_ptr[r] as usize..self.ins_row_ptr[r + 1] as usize]
    }

    /// Weight of the `k`-th insert slot (an index into `ins_col`).
    #[inline]
    pub fn ins_weight(&self, k: usize) -> Weight {
        if self.ins_wgt.is_empty() {
            1.0
        } else {
            self.ins_wgt[k]
        }
    }

    /// Sorted tombstoned sources of local row `r`.
    #[inline]
    pub fn row_tombs(&self, r: usize) -> &[VertexId] {
        &self.tomb_src[self.tomb_row_ptr[r] as usize..self.tomb_row_ptr[r + 1] as usize]
    }

    /// Does a tombstone kill base edge `(src, lo + r)`?
    #[inline]
    pub fn is_tombstoned(&self, r: usize, src: VertexId) -> bool {
        self.row_tombs(r).binary_search(&src).is_ok()
    }

    /// Merge with the base shard into a standalone CSR: per row, base
    /// survivors in base order followed by the inserts in insertion order —
    /// the exact row layout `Csr::from_edges_weighted`'s stable counting
    /// sort produces for the final edge list, so a compacted shard replays
    /// the merged stream bit-for-bit.
    pub fn merge(&self, base: &Csr) -> Csr {
        assert_eq!((base.lo, base.hi), (self.lo, self.hi), "delta/base interval mismatch");
        let rows = self.num_rows();
        let weighted = base.is_weighted() || self.is_weighted();
        let cap = base.num_edges() + self.ins_count();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col = Vec::with_capacity(cap);
        let mut wgt = if weighted { Vec::with_capacity(cap) } else { Vec::new() };
        row_ptr.push(0u32);
        for r in 0..rows {
            let (s, e) = (base.row_ptr[r] as usize, base.row_ptr[r + 1] as usize);
            let tombs = self.row_tombs(r);
            for k in s..e {
                let u = base.col[k];
                if tombs.binary_search(&u).is_ok() {
                    continue;
                }
                col.push(u);
                if weighted {
                    wgt.push(base.weight(k));
                }
            }
            let (is_, ie) = (
                self.ins_row_ptr[r] as usize,
                self.ins_row_ptr[r + 1] as usize,
            );
            for k in is_..ie {
                col.push(self.ins_col[k]);
                if weighted {
                    wgt.push(self.ins_weight(k));
                }
            }
            row_ptr.push(col.len() as u32);
        }
        Csr { lo: self.lo, hi: self.hi, row_ptr, col, wgt }
    }

    /// Effective edge count of the merged shard given the base edge count.
    pub fn effective_edges(&self, base_edges: u64) -> u64 {
        base_edges.saturating_sub(self.dropped_base) + self.ins_count() as u64
    }

    /// Approximate resident memory of the decoded delta (Fig 11 honesty:
    /// the engine keeps every delta shard in memory).
    pub fn resident_bytes(&self) -> usize {
        (self.ins_row_ptr.len()
            + self.tomb_row_ptr.len()
            + self.ins_col.len()
            + self.tomb_src.len()
            + self.ins_wgt.len())
            * 4
            + 8
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        put_u32(&mut payload, self.lo);
        put_u32(&mut payload, self.hi);
        put_u32s(&mut payload, &self.ins_row_ptr);
        put_u32s(&mut payload, &self.ins_col);
        put_f32s(&mut payload, &self.ins_wgt);
        put_u32s(&mut payload, &self.tomb_row_ptr);
        put_u32s(&mut payload, &self.tomb_src);
        put_u64(&mut payload, self.dropped_base);
        frame(SHARD_MAGIC, SHARD_VERSION, &payload)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let (version, payload) = unframe(SHARD_MAGIC, buf)?;
        anyhow::ensure!(version == SHARD_VERSION, "delta shard version {version}");
        let (lo, p) = get_u32(payload, 0)?;
        let (hi, p) = get_u32(payload, p)?;
        anyhow::ensure!(lo < hi, "delta shard interval empty [{lo},{hi})");
        let rows = (hi - lo) as usize;
        let (ins_row_ptr, p) = get_u32s(payload, p)?;
        let (ins_col, p) = get_u32s(payload, p)?;
        let (ins_wgt, p) = get_f32s(payload, p)?;
        let (tomb_row_ptr, p) = get_u32s(payload, p)?;
        let (tomb_src, p) = get_u32s(payload, p)?;
        let (dropped_base, p) = get_u64(payload, p)?;
        anyhow::ensure!(p == payload.len(), "delta shard trailing bytes");
        let d = DeltaShard {
            lo,
            hi,
            ins_row_ptr,
            ins_col,
            ins_wgt,
            tomb_row_ptr,
            tomb_src,
            dropped_base,
        };
        d.validate(rows)?;
        Ok(d)
    }

    fn validate(&self, rows: usize) -> Result<()> {
        let check_ptrs = |ptr: &[u32], len: usize, what: &str| -> Result<()> {
            anyhow::ensure!(ptr.len() == rows + 1, "{what} row_ptr length");
            anyhow::ensure!(ptr[0] == 0, "{what} row_ptr[0]");
            anyhow::ensure!(ptr[rows] as usize == len, "{what} row_ptr tail");
            anyhow::ensure!(ptr.windows(2).all(|w| w[0] <= w[1]), "{what} row_ptr monotone");
            Ok(())
        };
        check_ptrs(&self.ins_row_ptr, self.ins_col.len(), "insert")?;
        check_ptrs(&self.tomb_row_ptr, self.tomb_src.len(), "tombstone")?;
        anyhow::ensure!(
            self.ins_wgt.is_empty() || self.ins_wgt.len() == self.ins_col.len(),
            "insert weight lane length"
        );
        for r in 0..rows {
            let t = self.row_tombs(r);
            anyhow::ensure!(t.windows(2).all(|w| w[0] < w[1]), "tombstones not sorted/unique");
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::write_file(path, &self.to_bytes())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_bytes(&io::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_batch() -> Vec<Mutation> {
        vec![
            Mutation::Insert { src: 3, dst: 11, weight: 0.5 },
            Mutation::Delete { src: 1, dst: 10 },
            Mutation::Insert { src: 0, dst: 12, weight: 1.0 },
        ]
    }

    #[test]
    fn log_roundtrips() {
        let b = sample_batch();
        assert_eq!(log_from_bytes(&log_to_bytes(&b)).unwrap(), b);
        assert_eq!(log_from_bytes(&log_to_bytes(&[])).unwrap(), vec![]);
    }

    #[test]
    fn log_rejects_corruption_and_truncation() {
        let bytes = log_to_bytes(&sample_batch());
        for cut in [0, 5, bytes.len() - 1] {
            assert!(log_from_bytes(&bytes[..cut]).is_err());
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(log_from_bytes(&bad).is_err());
    }

    #[test]
    fn text_form_parses_and_rejects() {
        let got = log_from_text("# comment\n+ 3 11 0.5\n- 1 10\n+ 0 12\n").unwrap();
        assert_eq!(got, sample_batch());
        assert!(log_from_text("* 1 2\n").is_err());
        assert!(log_from_text("+ 1\n").is_err());
        assert!(log_from_text("+ 1 x\n").is_err());
    }

    #[test]
    fn auto_detects_binary_and_text() {
        let dir = std::env::temp_dir().join(format!("gmp_delta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("b.gmdl");
        save_log(&sample_batch(), &bp).unwrap();
        assert_eq!(load_log_auto(&bp).unwrap(), sample_batch());
        let tp = dir.join("t.txt");
        std::fs::write(&tp, "+ 3 11 0.5\n- 1 10\n+ 0 12\n").unwrap();
        assert_eq!(load_log_auto(&tp).unwrap(), sample_batch());
    }

    fn sample_delta() -> DeltaShard {
        // interval [10, 13): row 0 inserts (5,2.0) then (7,0.25); row 1
        // tombstones {1, 4}; row 2 both
        DeltaShard::from_rows(
            10,
            13,
            &[vec![(5, 2.0), (7, 0.25)], vec![], vec![(9, 1.5)]],
            &[vec![], vec![4, 1], vec![2]],
            3,
            true,
        )
    }

    #[test]
    fn delta_shard_roundtrips_and_validates() {
        let d = sample_delta();
        let e = DeltaShard::from_bytes(&d.to_bytes()).unwrap();
        assert_eq!(d, e);
        assert_eq!(e.ins_count(), 3);
        assert_eq!(e.num_tombstones(), 3);
        assert_eq!(e.ins_sources(0), &[5, 7]);
        assert_eq!(e.row_tombs(1), &[1, 4], "tombstones normalized sorted");
        assert!(e.is_tombstoned(1, 4) && !e.is_tombstoned(1, 5));
        assert_eq!(e.effective_edges(10), 10 - 3 + 3);

        let bytes = d.to_bytes();
        for cut in [0, 7, bytes.len() - 1] {
            assert!(DeltaShard::from_bytes(&bytes[..cut]).is_err());
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert!(DeltaShard::from_bytes(&bad).is_err());
    }

    #[test]
    fn merge_preserves_base_order_filters_tombs_appends_inserts() {
        // base [10,13): row10 <- {1,2}, row11 <- {1,4,6}, row12 <- {2}
        let base = Csr::from_edges(
            10,
            13,
            &[(1, 10), (2, 10), (1, 11), (4, 11), (6, 11), (2, 12)],
        );
        let d = sample_delta();
        let m = d.merge(&base);
        assert_eq!(m.in_neighbors(10), &[1, 2, 5, 7]);
        assert_eq!(m.in_neighbors(11), &[6], "tombstoned sources dropped");
        assert_eq!(m.in_neighbors(12), &[9]);
        assert!(m.is_weighted());
        // base edges carry unit weight, inserts their own
        assert_eq!(m.in_weights(10), &[1.0, 1.0, 2.0, 0.25]);
        m.validate().unwrap();
    }

    #[test]
    fn saved_values_roundtrip_with_epoch_tag() {
        use crate::graph::AnyValues;
        let dir = std::env::temp_dir().join(format!("gmp_gmvv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("values_wcc.gmv");
        let vals = AnyValues::F32(vec![0.5, f32::INFINITY, -1.0]);
        save_values(&p, 3, &vals).unwrap();
        let (epoch, got) = load_values(&p).unwrap();
        assert_eq!(epoch, 3);
        assert_eq!(got, vals);
        // u64 lane too
        save_values(&p, 9, &AnyValues::U64(vec![1, u64::MAX])).unwrap();
        let (epoch, got) = load_values(&p).unwrap();
        assert_eq!((epoch, got), (9, AnyValues::U64(vec![1, u64::MAX])));
        let mut bad = std::fs::read(&p).unwrap();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x08;
        std::fs::write(&p, &bad).unwrap();
        assert!(load_values(&p).is_err());
    }

    #[test]
    fn watch_state_roundtrips_and_rejects_corruption() {
        use crate::graph::AnyValues;
        let dir = std::env::temp_dir().join(format!("gmp_gmcs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("watch_spmv.gmw");
        let state = WatchState {
            epoch: 7,
            window: 3,
            window_epochs: vec![5, 6, 7],
            last_changed: vec![1, 4, 200],
            values: AnyValues::F64(vec![0.25, f64::NEG_INFINITY]),
        };
        save_watch(&p, &state).unwrap();
        assert_eq!(load_watch(&p).unwrap(), state);
        // unbounded window, empty changed-set
        let s2 = WatchState {
            epoch: 0,
            window: 0,
            window_epochs: vec![],
            last_changed: vec![],
            values: AnyValues::U32(vec![9]),
        };
        save_watch(&p, &s2).unwrap();
        assert_eq!(load_watch(&p).unwrap(), s2);
        let mut bad = std::fs::read(&p).unwrap();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x04;
        std::fs::write(&p, &bad).unwrap();
        assert!(load_watch(&p).is_err(), "CRC must catch the flip");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn unweighted_delta_on_unweighted_base_stays_unweighted() {
        let base = Csr::from_edges(0, 2, &[(1, 0)]);
        let d = DeltaShard::from_rows(0, 2, &[vec![(3, 1.0)], vec![]], &[vec![], vec![]], 0, false);
        let m = d.merge(&base);
        assert!(!m.is_weighted());
        assert_eq!(m.in_neighbors(0), &[1, 3]);
    }
}
