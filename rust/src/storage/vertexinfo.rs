//! The vertex information file (paper §II-B): per-vertex in-degree and
//! out-degree arrays (and, at program end, the final vertex values).
//! Framed binary (`GMVI`), CRC-checked.

use std::path::Path;

use anyhow::Result;

use crate::graph::Degrees;
use crate::storage::format::{frame, get_f32s, get_u32s, put_f32s, put_u32s, unframe};
use crate::storage::io;

const MAGIC: &[u8; 4] = b"GMVI";
const VERSION: u32 = 1;

/// Vertex info: degrees plus optional persisted values.
#[derive(Debug, Clone, Default)]
pub struct VertexInfo {
    pub degrees: Degrees,
    /// Final vertex values (empty until a run persists results).
    pub values: Vec<f32>,
}

impl VertexInfo {
    pub fn new(degrees: Degrees) -> Self {
        Self { degrees, values: Vec::new() }
    }

    pub fn num_vertices(&self) -> usize {
        self.degrees.in_deg.len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        put_u32s(&mut payload, &self.degrees.in_deg);
        put_u32s(&mut payload, &self.degrees.out_deg);
        put_f32s(&mut payload, &self.values);
        frame(MAGIC, VERSION, &payload)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let (version, payload) = unframe(MAGIC, buf)?;
        anyhow::ensure!(version == VERSION, "vertexinfo version {version}");
        let (in_deg, p) = get_u32s(payload, 0)?;
        let (out_deg, p) = get_u32s(payload, p)?;
        let (values, p) = get_f32s(payload, p)?;
        anyhow::ensure!(p == payload.len(), "vertexinfo trailing bytes");
        anyhow::ensure!(in_deg.len() == out_deg.len(), "degree arrays disagree");
        anyhow::ensure!(
            values.is_empty() || values.len() == in_deg.len(),
            "values length mismatch"
        );
        Ok(Self { degrees: Degrees { in_deg, out_deg }, values })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::write_file(path, &self.to_bytes())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_bytes(&io::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> VertexInfo {
        VertexInfo {
            degrees: Degrees { in_deg: vec![1, 2, 3], out_deg: vec![3, 2, 1] },
            values: vec![0.5, 1.5, -2.0],
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let v = sample();
        let w = VertexInfo::from_bytes(&v.to_bytes()).unwrap();
        assert_eq!(w.degrees.in_deg, v.degrees.in_deg);
        assert_eq!(w.degrees.out_deg, v.degrees.out_deg);
        assert_eq!(w.values, v.values);
    }

    #[test]
    fn empty_values_ok() {
        let mut v = sample();
        v.values.clear();
        let w = VertexInfo::from_bytes(&v.to_bytes()).unwrap();
        assert!(w.values.is_empty());
    }

    #[test]
    fn corrupt_rejected() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(VertexInfo::from_bytes(&bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_vi_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vertexinfo.bin");
        let v = sample();
        v.save(&path).unwrap();
        let w = VertexInfo::load(&path).unwrap();
        assert_eq!(w.values, v.values);
    }
}
