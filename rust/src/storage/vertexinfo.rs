//! The vertex information file (paper §II-B): per-vertex in-degree and
//! out-degree arrays (and, at program end, the final vertex values).
//! Framed binary (`GMVI`), CRC-checked.
//!
//! Version 2 stores the persisted values as a lane-tagged
//! [`AnyValues`] array, so any vertex-value lane (`u32`/`u64`/`f32`/`f64`)
//! round-trips; version 1 files (bare `f32[]` values) still load.

use std::path::Path;

use anyhow::Result;

use crate::graph::{AnyValues, Degrees};
use crate::storage::format::{
    frame, get_any_values, get_f32s, get_u32s, put_any_values, put_u32s, unframe,
};
use crate::storage::io;

const MAGIC: &[u8; 4] = b"GMVI";
/// Current written version (v2 = lane-tagged values).
const VERSION: u32 = 2;
/// Oldest readable version (v1 = bare f32 values).
const MIN_VERSION: u32 = 1;

/// Vertex info: degrees plus optional persisted values (any lane).
#[derive(Debug, Clone, Default)]
pub struct VertexInfo {
    pub degrees: Degrees,
    /// Final vertex values (empty until a run persists results).
    pub values: AnyValues,
}

impl VertexInfo {
    pub fn new(degrees: Degrees) -> Self {
        Self { degrees, values: AnyValues::default() }
    }

    pub fn num_vertices(&self) -> usize {
        self.degrees.in_deg.len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        put_u32s(&mut payload, &self.degrees.in_deg);
        put_u32s(&mut payload, &self.degrees.out_deg);
        put_any_values(&mut payload, &self.values);
        frame(MAGIC, VERSION, &payload)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let (version, payload) = unframe(MAGIC, buf)?;
        anyhow::ensure!(
            (MIN_VERSION..=VERSION).contains(&version),
            "vertexinfo version {version} (readable: {MIN_VERSION}..={VERSION})"
        );
        let (in_deg, p) = get_u32s(payload, 0)?;
        let (out_deg, p) = get_u32s(payload, p)?;
        let (values, p) = if version >= 2 {
            get_any_values(payload, p)?
        } else {
            let (vals, p) = get_f32s(payload, p)?;
            (AnyValues::F32(vals), p)
        };
        anyhow::ensure!(p == payload.len(), "vertexinfo trailing bytes");
        anyhow::ensure!(in_deg.len() == out_deg.len(), "degree arrays disagree");
        anyhow::ensure!(
            values.is_empty() || values.len() == in_deg.len(),
            "values length mismatch"
        );
        Ok(Self { degrees: Degrees { in_deg, out_deg }, values })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::write_file(path, &self.to_bytes())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_bytes(&io::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::format::put_f32s;

    fn sample() -> VertexInfo {
        VertexInfo {
            degrees: Degrees { in_deg: vec![1, 2, 3], out_deg: vec![3, 2, 1] },
            values: AnyValues::F32(vec![0.5, 1.5, -2.0]),
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let v = sample();
        let w = VertexInfo::from_bytes(&v.to_bytes()).unwrap();
        assert_eq!(w.degrees.in_deg, v.degrees.in_deg);
        assert_eq!(w.degrees.out_deg, v.degrees.out_deg);
        assert_eq!(w.values, v.values);
    }

    #[test]
    fn typed_values_roundtrip_all_lanes() {
        let degrees = Degrees { in_deg: vec![0, 1], out_deg: vec![1, 0] };
        let lanes: Vec<AnyValues> = vec![
            AnyValues::U32(vec![7, u32::MAX]),
            AnyValues::U64(vec![0, u64::MAX]),
            AnyValues::F32(vec![f32::INFINITY, -1.0]),
            AnyValues::F64(vec![2.5, 0.0]),
        ];
        for values in lanes {
            let v = VertexInfo { degrees: degrees.clone(), values: values.clone() };
            let w = VertexInfo::from_bytes(&v.to_bytes()).unwrap();
            assert_eq!(w.values, values);
        }
    }

    #[test]
    fn v1_payload_loads_as_f32_values() {
        // hand-build a v1 payload: degrees + bare f32 values
        let mut payload = Vec::new();
        put_u32s(&mut payload, &[1, 2]);
        put_u32s(&mut payload, &[2, 1]);
        put_f32s(&mut payload, &[0.25, 4.0]);
        let bytes = frame(MAGIC, 1, &payload);
        let v = VertexInfo::from_bytes(&bytes).unwrap();
        assert_eq!(v.values, AnyValues::F32(vec![0.25, 4.0]));
        assert_eq!(v.degrees.in_deg, vec![1, 2]);
    }

    #[test]
    fn empty_values_ok() {
        let mut v = sample();
        v.values = AnyValues::default();
        let w = VertexInfo::from_bytes(&v.to_bytes()).unwrap();
        assert!(w.values.is_empty());
    }

    #[test]
    fn corrupt_rejected() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(VertexInfo::from_bytes(&bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_vi_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vertexinfo.bin");
        let v = sample();
        v.save(&path).unwrap();
        let w = VertexInfo::load(&path).unwrap();
        assert_eq!(w.values, v.values);
    }
}
