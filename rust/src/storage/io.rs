//! Instrumented file I/O: global byte counters + optional HDD throttle.
//!
//! All engines (GraphMP and the baselines) route disk traffic through
//! [`read_file`] / [`write_file`], so `IoStats` measures exactly the
//! quantities Table II analyzes (data read / data write per iteration).
//!
//! The **throttle** simulates the paper's testbed disks: the container's
//! page cache makes every "disk" read a memory copy, which would erase the
//! I/O-bound regime the paper lives in.  With a throttle of `B` bytes/s,
//! each read/write of `n` bytes sleeps `n/B` (minus time already spent),
//! recreating HDD-era cost *ratios* without needing 4×4 TB of spinning
//! rust.  Disabled by default; benches enable it explicitly.

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

/// Global I/O accounting (monotonic counters; snapshot + delta pattern).
#[derive(Debug, Default)]
pub struct IoStats {
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub read_ops: AtomicU64,
    pub write_ops: AtomicU64,
    /// Simulated disk time added by the throttle, in nanoseconds.
    pub throttle_ns: AtomicU64,
}

/// Point-in-time snapshot of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IoSnapshot {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ops: u64,
    pub write_ops: u64,
    pub throttle_ns: u64,
}

impl IoSnapshot {
    /// Delta between two snapshots (self = later).
    pub fn since(&self, earlier: &IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read - earlier.bytes_read,
            bytes_written: self.bytes_written - earlier.bytes_written,
            read_ops: self.read_ops - earlier.read_ops,
            write_ops: self.write_ops - earlier.write_ops,
            throttle_ns: self.throttle_ns - earlier.throttle_ns,
        }
    }
}

static GLOBAL: IoStats = IoStats {
    bytes_read: AtomicU64::new(0),
    bytes_written: AtomicU64::new(0),
    read_ops: AtomicU64::new(0),
    write_ops: AtomicU64::new(0),
    throttle_ns: AtomicU64::new(0),
};

/// Throttle bandwidth in bytes/s; 0 = disabled.
static THROTTLE_BPS: AtomicU64 = AtomicU64::new(0);

/// Enable/disable the HDD bandwidth model (bytes per second; 0 disables).
/// The paper's 4×HDD RAID5 sustains ~300-400 MB/s sequential; benches use
/// `set_throttle(300 << 20)`.
pub fn set_throttle(bytes_per_sec: u64) {
    THROTTLE_BPS.store(bytes_per_sec, Ordering::Relaxed);
}

pub fn throttle() -> u64 {
    THROTTLE_BPS.load(Ordering::Relaxed)
}

/// Snapshot the global counters.
pub fn snapshot() -> IoSnapshot {
    IoSnapshot {
        bytes_read: GLOBAL.bytes_read.load(Ordering::Relaxed),
        bytes_written: GLOBAL.bytes_written.load(Ordering::Relaxed),
        read_ops: GLOBAL.read_ops.load(Ordering::Relaxed),
        write_ops: GLOBAL.write_ops.load(Ordering::Relaxed),
        throttle_ns: GLOBAL.throttle_ns.load(Ordering::Relaxed),
    }
}

fn apply_throttle(bytes: u64, elapsed: Duration) {
    let bps = THROTTLE_BPS.load(Ordering::Relaxed);
    if bps == 0 || bytes == 0 {
        return;
    }
    let budget = Duration::from_secs_f64(bytes as f64 / bps as f64);
    if budget > elapsed {
        let sleep = budget - elapsed;
        GLOBAL.throttle_ns.fetch_add(sleep.as_nanos() as u64, Ordering::Relaxed);
        std::thread::sleep(sleep);
    }
}

/// Read a whole file through the accounting layer.
pub fn read_file(path: &Path) -> Result<Vec<u8>> {
    let t0 = Instant::now();
    let mut f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    GLOBAL.bytes_read.fetch_add(buf.len() as u64, Ordering::Relaxed);
    GLOBAL.read_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(buf.len() as u64, t0.elapsed());
    Ok(buf)
}

/// Write a whole file through the accounting layer.
pub fn write_file(path: &Path, data: &[u8]) -> Result<()> {
    let t0 = Instant::now();
    let mut f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    f.write_all(data)?;
    GLOBAL.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
    GLOBAL.write_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(data.len() as u64, t0.elapsed());
    Ok(())
}

/// Append to a file through the accounting layer (used by streaming
/// baselines writing update files).
pub fn append_file(path: &Path, data: &[u8]) -> Result<()> {
    let t0 = Instant::now();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("append {}", path.display()))?;
    f.write_all(data)?;
    GLOBAL.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
    GLOBAL.write_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(data.len() as u64, t0.elapsed());
    Ok(())
}

/// Account for a read performed outside [`read_file`]: the direct-I/O
/// reader (`storage::uring`) does its own syscalls but must hit the same
/// counters and throttle so the Table II stats and the HDD model see
/// identical traffic.  `elapsed` is the real wall time of the read, which
/// the throttle credits against the simulated disk budget.
pub fn account_read(bytes: u64, elapsed: Duration) {
    GLOBAL.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    GLOBAL.read_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(bytes, elapsed);
}

/// Account for a read served from an in-memory mock of disk (used by
/// baseline engines that model per-iteration re-reads without touching the
/// real filesystem in unit tests).
pub fn account_virtual_read(bytes: u64) {
    let t0 = Instant::now();
    GLOBAL.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    GLOBAL.read_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(bytes, t0.elapsed());
}

/// Account for a virtual write (see [`account_virtual_read`]).
pub fn account_virtual_write(bytes: u64) {
    let t0 = Instant::now();
    GLOBAL.bytes_written.fetch_add(bytes, Ordering::Relaxed);
    GLOBAL.write_ops.fetch_add(1, Ordering::Relaxed);
    apply_throttle(bytes, t0.elapsed());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gmp_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn counters_track_bytes() {
        let p = tmp("a.bin");
        let before = snapshot();
        write_file(&p, &[0u8; 1000]).unwrap();
        let data = read_file(&p).unwrap();
        assert_eq!(data.len(), 1000);
        let delta = snapshot().since(&before);
        assert!(delta.bytes_written >= 1000);
        assert!(delta.bytes_read >= 1000);
        assert!(delta.read_ops >= 1);
        assert!(delta.write_ops >= 1);
    }

    #[test]
    fn append_accumulates() {
        let p = tmp("b.bin");
        let _ = std::fs::remove_file(&p);
        append_file(&p, b"xx").unwrap();
        append_file(&p, b"yy").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"xxyy");
    }

    #[test]
    fn throttle_slows_virtual_io() {
        // 1 MiB at 10 MiB/s => ~100ms
        set_throttle(10 << 20);
        let t0 = Instant::now();
        account_virtual_read(1 << 20);
        let elapsed = t0.elapsed();
        set_throttle(0);
        assert!(elapsed >= Duration::from_millis(80), "throttle not applied: {elapsed:?}");
    }

    #[test]
    fn snapshot_delta_is_monotone() {
        let a = snapshot();
        account_virtual_write(123);
        let b = snapshot();
        let d = b.since(&a);
        assert!(d.bytes_written >= 123);
    }
}
