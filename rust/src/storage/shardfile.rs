//! Shard files (`shard_XXXX.gms`): one CSR edge shard per vertex interval
//! (paper §II-B, Figure 2).  Framed binary (`GMSH`), CRC-checked.
//!
//! Payload layout (version 2):
//! ```text
//! u32 lo, u32 hi                  vertex interval [lo, hi)
//! u32[] row_ptr                   (hi-lo)+1 entries
//! u32[] col                       source ids grouped by destination
//! f32[] wgt                       per-edge weights (len 0 = unweighted)
//! ```
//!
//! Version 1 (pre-weight-lane) payloads end after `col`; readers accept
//! both, and a v1 shard loads as an unweighted CSR that reproduces pre-v2
//! results bit-for-bit.  Writers always emit v2.

use std::path::Path;

use anyhow::Result;

use crate::graph::csr::Csr;
use crate::storage::format::{
    frame, get_f32s, get_u32, get_u32s, put_f32s, put_u32, put_u32s, unframe,
};
use crate::storage::io;

const MAGIC: &[u8; 4] = b"GMSH";
/// Current written version (v2 = optional weight lane).
const VERSION: u32 = 2;
/// Oldest readable version (v1 = unweighted payload without `wgt`).
const MIN_VERSION: u32 = 1;

/// Serialize a CSR shard to framed bytes (always version 2).
pub fn to_bytes(csr: &Csr) -> Vec<u8> {
    let mut payload = Vec::with_capacity(
        8 + (csr.row_ptr.len() + csr.col.len() + csr.wgt.len()) * 4 + 24,
    );
    put_u32(&mut payload, csr.lo);
    put_u32(&mut payload, csr.hi);
    put_u32s(&mut payload, &csr.row_ptr);
    put_u32s(&mut payload, &csr.col);
    put_f32s(&mut payload, &csr.wgt);
    frame(MAGIC, VERSION, &payload)
}

/// Deserialize + structurally validate a CSR shard (accepts v1 and v2).
pub fn from_bytes(buf: &[u8]) -> Result<Csr> {
    let (version, payload) = unframe(MAGIC, buf)?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "shard version {version} (readable: {MIN_VERSION}..={VERSION})"
    );
    let (lo, p) = get_u32(payload, 0)?;
    let (hi, p) = get_u32(payload, p)?;
    anyhow::ensure!(lo < hi, "shard interval empty [{lo},{hi})");
    let (row_ptr, p) = get_u32s(payload, p)?;
    let (col, p) = get_u32s(payload, p)?;
    let (wgt, p) = if version >= 2 {
        get_f32s(payload, p)?
    } else {
        (Vec::new(), p)
    };
    anyhow::ensure!(p == payload.len(), "shard trailing bytes");
    let csr = Csr { lo, hi, row_ptr, col, wgt };
    csr.validate()?;
    Ok(csr)
}

/// Serialize in the legacy v1 layout (no weight lane).  Only for
/// compatibility tests and migrating fixtures; `csr` must be unweighted.
pub fn to_bytes_v1(csr: &Csr) -> Vec<u8> {
    assert!(!csr.is_weighted(), "v1 layout cannot carry weights");
    let mut payload = Vec::with_capacity(8 + (csr.row_ptr.len() + csr.col.len()) * 4 + 16);
    put_u32(&mut payload, csr.lo);
    put_u32(&mut payload, csr.hi);
    put_u32s(&mut payload, &csr.row_ptr);
    put_u32s(&mut payload, &csr.col);
    frame(MAGIC, 1, &payload)
}

/// Write a shard through the accounting layer.
pub fn save(csr: &Csr, path: &Path) -> Result<()> {
    io::write_file(path, &to_bytes(csr))
}

/// Read a shard through the accounting layer.
pub fn load(path: &Path) -> Result<Csr> {
    from_bytes(&io::read_file(path)?)
}

/// On-disk size estimate without serializing (for cache budgeting).
pub fn estimated_bytes(csr: &Csr) -> usize {
    20 /* frame */ + 8 /* lo,hi */ + 24 /* array headers */
        + (csr.row_ptr.len() + csr.col.len() + csr.wgt.len()) * 4
}

// ---- zero-copy payload views -----------------------------------------------
//
// The compressed-domain gather path walks serialized shard bytes in place:
// `parse_layout` validates a framed buffer once (everything `from_bytes`
// checks — CRC, version, monotone `row_ptr`, array bounds) and records the
// section offsets in a `Copy` struct, and `PayloadLayout::view` then hands
// out an accessor whose `row_ptr`/`col`/`weight` reads are plain LE loads.
// No `Vec` is ever built, which is what makes a compressed-cache hit (or a
// fresh disk read) free of the decoded-CSR allocations.

/// Validated section offsets of a framed shard buffer (`Copy`, borrow-free
/// — safe to ship across threads next to the bytes it describes).
#[derive(Debug, Clone, Copy)]
pub struct PayloadLayout {
    pub lo: u32,
    pub hi: u32,
    /// Edge count (`col` length).
    pub num_edges: usize,
    pub weighted: bool,
    /// Byte offset of `row_ptr[0]` within the framed buffer.
    row_ptr_off: usize,
    /// Byte offset of `col[0]`.
    col_off: usize,
    /// Byte offset of `wgt[0]` (meaningful only when `weighted`).
    wgt_off: usize,
}

/// Parse + fully validate a framed shard buffer without materializing it.
/// Accepts exactly what [`from_bytes`] accepts (including v1 payloads).
pub fn parse_layout(buf: &[u8]) -> Result<PayloadLayout> {
    let (version, payload) = unframe(MAGIC, buf)?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "shard version {version} (readable: {MIN_VERSION}..={VERSION})"
    );
    // offsets below are relative to `buf`, so everything the view reads is
    // one add away from the framed bytes the cache/prefetcher already holds
    let base = buf.len() - 4 - payload.len();
    let (lo, p) = get_u32(payload, 0)?;
    let (hi, p) = get_u32(payload, p)?;
    anyhow::ensure!(lo < hi, "shard interval empty [{lo},{hi})");
    let rows = (hi - lo) as usize;

    let read_len = |pos: usize| -> Result<(usize, usize)> {
        anyhow::ensure!(payload.len() >= pos + 8, "array header truncated");
        let n = u64::from_le_bytes(payload[pos..pos + 8].try_into().unwrap()) as usize;
        let start = pos + 8;
        let room = payload.len().saturating_sub(start);
        anyhow::ensure!(
            n.checked_mul(4).is_some_and(|bytes| room >= bytes),
            "array payload truncated"
        );
        Ok((n, start))
    };
    let (rp_len, rp_start) = read_len(p)?;
    anyhow::ensure!(rp_len == rows + 1, "row_ptr length");
    let (col_len, col_start) = read_len(rp_start + rp_len * 4)?;
    let (wgt_len, wgt_start) = if version >= 2 {
        read_len(col_start + col_len * 4)?
    } else {
        (0, col_start + col_len * 4)
    };
    anyhow::ensure!(
        wgt_len == 0 || wgt_len == col_len,
        "weight lane length != col length"
    );
    anyhow::ensure!(wgt_start + wgt_len * 4 == payload.len(), "shard trailing bytes");

    // structural validation, mirroring Csr::validate
    let rp = |i: usize| {
        u32::from_le_bytes(payload[rp_start + i * 4..rp_start + i * 4 + 4].try_into().unwrap())
    };
    anyhow::ensure!(rp(0) == 0, "row_ptr[0] != 0");
    anyhow::ensure!(rp(rows) as usize == col_len, "row_ptr tail != col len");
    for i in 0..rows {
        anyhow::ensure!(rp(i) <= rp(i + 1), "row_ptr not monotone");
    }
    Ok(PayloadLayout {
        lo,
        hi,
        num_edges: col_len,
        weighted: wgt_len > 0,
        row_ptr_off: base + rp_start,
        col_off: base + col_start,
        wgt_off: base + wgt_start,
    })
}

impl PayloadLayout {
    pub fn num_rows(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    /// Accessor over `buf`, which must be the exact buffer this layout was
    /// parsed from (same length; offsets are positional).
    pub fn view<'a>(&self, buf: &'a [u8]) -> PayloadView<'a> {
        PayloadView { layout: *self, buf }
    }
}

/// In-place reader over a validated framed shard buffer — the borrowed
/// counterpart of a decoded [`Csr`].
#[derive(Clone, Copy)]
pub struct PayloadView<'a> {
    layout: PayloadLayout,
    buf: &'a [u8],
}

impl PayloadView<'_> {
    #[inline]
    fn u32_at(&self, off: usize) -> u32 {
        u32::from_le_bytes(self.buf[off..off + 4].try_into().unwrap())
    }

    pub fn lo(&self) -> u32 {
        self.layout.lo
    }

    pub fn num_rows(&self) -> usize {
        self.layout.num_rows()
    }

    pub fn num_edges(&self) -> usize {
        self.layout.num_edges
    }

    pub fn is_weighted(&self) -> bool {
        self.layout.weighted
    }

    /// `row_ptr[i]` as an edge index (i ≤ num_rows).
    #[inline]
    pub fn row_ptr(&self, i: usize) -> usize {
        self.u32_at(self.layout.row_ptr_off + i * 4) as usize
    }

    /// Source id of edge slot `k`.
    #[inline]
    pub fn col(&self, k: usize) -> u32 {
        self.u32_at(self.layout.col_off + k * 4)
    }

    /// Weight of edge slot `k` (1.0 when unweighted).
    #[inline]
    pub fn weight(&self, k: usize) -> f32 {
        if self.layout.weighted {
            f32::from_bits(self.u32_at(self.layout.wgt_off + k * 4))
        } else {
            1.0
        }
    }
}

impl<'a> PayloadView<'a> {
    /// Reinterpret `buf[off..]` as `len` little-endian 4-byte scalars when
    /// the section happens to sit on a 4-byte boundary.  The framed
    /// container gives no alignment promise (offsets depend on the header
    /// and whoever allocated the buffer), so this is a runtime check, not
    /// an invariant — and the cast is only meaningful where the in-memory
    /// scalar layout *is* the wire layout, i.e. little-endian targets.
    /// Everything bounds-relevant was validated by [`parse_layout`].
    #[inline]
    fn run_at<T: Copy>(&self, off: usize, len: usize) -> Option<&'a [T]> {
        debug_assert_eq!(std::mem::size_of::<T>(), 4);
        if cfg!(target_endian = "big") {
            return None;
        }
        let bytes = self.buf.get(off..off + len * 4)?;
        let ptr = bytes.as_ptr();
        if ptr.align_offset(std::mem::align_of::<T>()) != 0 {
            return None;
        }
        // SAFETY: `bytes` covers exactly `len * 4` in-bounds bytes of a
        // live `&'a [u8]`, the pointer is aligned for `T` (checked above),
        // and `T` is a 4-byte POD scalar (u32/f32) whose every bit pattern
        // is valid; on little-endian targets the wire format matches the
        // in-memory representation.
        Some(unsafe { std::slice::from_raw_parts(ptr as *const T, len) })
    }

    /// Edge slots `[s, e)` of `col` as a borrowed slice, when the buffer
    /// is aligned for it (`None` → use per-slot [`Self::col`]).
    #[inline]
    pub fn col_run(&self, s: usize, e: usize) -> Option<&'a [u32]> {
        debug_assert!(s <= e && e <= self.layout.num_edges);
        self.run_at(self.layout.col_off + s * 4, e - s)
    }

    /// Edge slots `[s, e)` of the weight lane; `None` when unweighted or
    /// unaligned (`None` → use per-slot [`Self::weight`]).
    #[inline]
    pub fn weight_run(&self, s: usize, e: usize) -> Option<&'a [f32]> {
        debug_assert!(s <= e && e <= self.layout.num_edges);
        if !self.layout.weighted {
            return None;
        }
        self.run_at(self.layout.wgt_off + s * 4, e - s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> Csr {
        Csr::from_edges(10, 13, &[(1, 10), (2, 10), (3, 12), (9, 11), (0, 10)])
    }

    fn sample_weighted() -> Csr {
        Csr::from_edges_weighted(
            10,
            13,
            &[(1, 10), (2, 10), (3, 12), (9, 11), (0, 10)],
            &[0.25, 0.5, 0.75, 1.25, 2.0],
        )
    }

    #[test]
    fn bytes_roundtrip() {
        let a = sample();
        let b = from_bytes(&to_bytes(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn weighted_bytes_roundtrip() {
        let a = sample_weighted();
        let b = from_bytes(&to_bytes(&a)).unwrap();
        assert_eq!(a, b);
        assert!(b.is_weighted());
    }

    #[test]
    fn v1_payloads_still_load_unweighted() {
        let a = sample();
        let v1 = to_bytes_v1(&a);
        let b = from_bytes(&v1).unwrap();
        assert_eq!(a, b);
        assert!(!b.is_weighted());
        // and the v1 bytes differ from v2 only by the empty weight array
        assert_eq!(to_bytes(&a).len(), v1.len() + 8);
    }

    #[test]
    fn estimated_size_is_exact_here() {
        let a = sample();
        assert_eq!(estimated_bytes(&a), to_bytes(&a).len());
        let w = sample_weighted();
        assert_eq!(estimated_bytes(&w), to_bytes(&w).len());
    }

    #[test]
    fn corrupt_and_truncated_rejected() {
        let bytes = to_bytes(&sample());
        for cut in [0, 5, bytes.len() - 1] {
            assert!(from_bytes(&bytes[..cut]).is_err());
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(from_bytes(&bad).is_err());
    }

    #[test]
    fn unknown_version_rejected() {
        let a = sample();
        let mut payload = Vec::new();
        put_u32(&mut payload, a.lo);
        put_u32(&mut payload, a.hi);
        put_u32s(&mut payload, &a.row_ptr);
        put_u32s(&mut payload, &a.col);
        put_f32s(&mut payload, &a.wgt);
        let bytes = frame(MAGIC, VERSION + 1, &payload);
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard_0000.gms");
        let a = sample_weighted();
        save(&a, &path).unwrap();
        assert_eq!(load(&path).unwrap(), a);
    }

    fn assert_view_matches(csr: &Csr, buf: &[u8]) {
        let layout = parse_layout(buf).unwrap();
        let view = layout.view(buf);
        assert_eq!((view.lo(), layout.hi), (csr.lo, csr.hi));
        assert_eq!(view.num_rows(), csr.num_vertices());
        assert_eq!(view.num_edges(), csr.num_edges());
        assert_eq!(view.is_weighted(), csr.is_weighted());
        for i in 0..=csr.num_vertices() {
            assert_eq!(view.row_ptr(i), csr.row_ptr[i] as usize);
        }
        for k in 0..csr.num_edges() {
            assert_eq!(view.col(k), csr.col[k]);
            assert_eq!(view.weight(k).to_bits(), csr.weight(k).to_bits());
        }
    }

    #[test]
    fn payload_view_reads_v1_and_v2_in_place() {
        let w = sample_weighted();
        assert_view_matches(&w, &to_bytes(&w));
        let u = sample();
        assert_view_matches(&u, &to_bytes(&u));
        assert_view_matches(&u, &to_bytes_v1(&u));
    }

    #[test]
    fn payload_runs_match_per_slot_reads() {
        for bytes in [to_bytes(&sample_weighted()), to_bytes(&sample()), to_bytes_v1(&sample())] {
            let layout = parse_layout(&bytes).unwrap();
            let view = layout.view(&bytes);
            let m = view.num_edges();
            if let Some(cols) = view.col_run(0, m) {
                assert_eq!(cols.len(), m);
                for (k, &c) in cols.iter().enumerate() {
                    assert_eq!(c, view.col(k));
                }
            }
            match view.weight_run(0, m) {
                Some(w) => {
                    assert!(view.is_weighted());
                    for (k, &x) in w.iter().enumerate() {
                        assert_eq!(x.to_bits(), view.weight(k).to_bits());
                    }
                }
                None => {} // unweighted, or the allocator gave odd alignment
            }
            if let Some(cols) = view.col_run(1, m) {
                assert_eq!(cols.len(), m - 1);
                assert_eq!(cols[0], view.col(1));
            }
            if let Some(r) = view.col_run(2, 2) {
                assert!(r.is_empty());
            }

            // a deliberately shifted copy: runs are either refused
            // (alignment check) or still read the same slots
            let mut shifted = vec![0u8; bytes.len() + 1];
            shifted[1..].copy_from_slice(&bytes);
            let l2 = parse_layout(&shifted[1..]).unwrap();
            let v2 = l2.view(&shifted[1..]);
            if let Some(cols) = v2.col_run(0, m) {
                for (k, &c) in cols.iter().enumerate() {
                    assert_eq!(c, v2.col(k));
                }
            }
        }
    }

    #[test]
    fn payload_layout_rejects_what_from_bytes_rejects() {
        let bytes = to_bytes(&sample_weighted());
        for cut in [0, 5, bytes.len() - 1] {
            assert!(parse_layout(&bytes[..cut]).is_err());
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(parse_layout(&bad).is_err(), "CRC damage must be caught");
    }

    #[test]
    fn prop_arbitrary_shards_roundtrip() {
        prop::check(0x5A4D, 40, |g| {
            let lo = g.usize_in(0, 100) as u32;
            let width = g.usize_in(1, 64) as u32;
            let m = g.usize_in(0, 300);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    (
                        g.usize_in(0, 1000) as u32,
                        lo + g.usize_in(0, width as usize) as u32,
                    )
                })
                .collect();
            let weighted = g.bool(0.5);
            let weights: Vec<f32> = if weighted {
                (0..m).map(|_| (g.usize_in(1, 16) as f32) * 0.25).collect()
            } else {
                Vec::new()
            };
            let a = Csr::from_edges_weighted(lo, lo + width, &edges, &weights);
            let b = from_bytes(&to_bytes(&a)).unwrap();
            assert_eq!(a, b);
        });
    }
}
