//! Shard files (`shard_XXXX.gms`): one CSR edge shard per vertex interval
//! (paper §II-B, Figure 2).  Framed binary (`GMSH`), CRC-checked.
//!
//! Payload layout (version 2):
//! ```text
//! u32 lo, u32 hi                  vertex interval [lo, hi)
//! u32[] row_ptr                   (hi-lo)+1 entries
//! u32[] col                       source ids grouped by destination
//! f32[] wgt                       per-edge weights (len 0 = unweighted)
//! ```
//!
//! Version 1 (pre-weight-lane) payloads end after `col`; readers accept
//! both, and a v1 shard loads as an unweighted CSR that reproduces pre-v2
//! results bit-for-bit.  Writers always emit v2.

use std::path::Path;

use anyhow::Result;

use crate::graph::csr::Csr;
use crate::storage::format::{
    frame, get_f32s, get_u32, get_u32s, put_f32s, put_u32, put_u32s, unframe,
};
use crate::storage::io;

const MAGIC: &[u8; 4] = b"GMSH";
/// Current written version (v2 = optional weight lane).
const VERSION: u32 = 2;
/// Oldest readable version (v1 = unweighted payload without `wgt`).
const MIN_VERSION: u32 = 1;

/// Serialize a CSR shard to framed bytes (always version 2).
pub fn to_bytes(csr: &Csr) -> Vec<u8> {
    let mut payload = Vec::with_capacity(
        8 + (csr.row_ptr.len() + csr.col.len() + csr.wgt.len()) * 4 + 24,
    );
    put_u32(&mut payload, csr.lo);
    put_u32(&mut payload, csr.hi);
    put_u32s(&mut payload, &csr.row_ptr);
    put_u32s(&mut payload, &csr.col);
    put_f32s(&mut payload, &csr.wgt);
    frame(MAGIC, VERSION, &payload)
}

/// Deserialize + structurally validate a CSR shard (accepts v1 and v2).
pub fn from_bytes(buf: &[u8]) -> Result<Csr> {
    let (version, payload) = unframe(MAGIC, buf)?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "shard version {version} (readable: {MIN_VERSION}..={VERSION})"
    );
    let (lo, p) = get_u32(payload, 0)?;
    let (hi, p) = get_u32(payload, p)?;
    anyhow::ensure!(lo < hi, "shard interval empty [{lo},{hi})");
    let (row_ptr, p) = get_u32s(payload, p)?;
    let (col, p) = get_u32s(payload, p)?;
    let (wgt, p) = if version >= 2 {
        get_f32s(payload, p)?
    } else {
        (Vec::new(), p)
    };
    anyhow::ensure!(p == payload.len(), "shard trailing bytes");
    let csr = Csr { lo, hi, row_ptr, col, wgt };
    csr.validate()?;
    Ok(csr)
}

/// Serialize in the legacy v1 layout (no weight lane).  Only for
/// compatibility tests and migrating fixtures; `csr` must be unweighted.
pub fn to_bytes_v1(csr: &Csr) -> Vec<u8> {
    assert!(!csr.is_weighted(), "v1 layout cannot carry weights");
    let mut payload = Vec::with_capacity(8 + (csr.row_ptr.len() + csr.col.len()) * 4 + 16);
    put_u32(&mut payload, csr.lo);
    put_u32(&mut payload, csr.hi);
    put_u32s(&mut payload, &csr.row_ptr);
    put_u32s(&mut payload, &csr.col);
    frame(MAGIC, 1, &payload)
}

/// Write a shard through the accounting layer.
pub fn save(csr: &Csr, path: &Path) -> Result<()> {
    io::write_file(path, &to_bytes(csr))
}

/// Read a shard through the accounting layer.
pub fn load(path: &Path) -> Result<Csr> {
    from_bytes(&io::read_file(path)?)
}

/// On-disk size estimate without serializing (for cache budgeting).
pub fn estimated_bytes(csr: &Csr) -> usize {
    20 /* frame */ + 8 /* lo,hi */ + 24 /* array headers */
        + (csr.row_ptr.len() + csr.col.len() + csr.wgt.len()) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> Csr {
        Csr::from_edges(10, 13, &[(1, 10), (2, 10), (3, 12), (9, 11), (0, 10)])
    }

    fn sample_weighted() -> Csr {
        Csr::from_edges_weighted(
            10,
            13,
            &[(1, 10), (2, 10), (3, 12), (9, 11), (0, 10)],
            &[0.25, 0.5, 0.75, 1.25, 2.0],
        )
    }

    #[test]
    fn bytes_roundtrip() {
        let a = sample();
        let b = from_bytes(&to_bytes(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn weighted_bytes_roundtrip() {
        let a = sample_weighted();
        let b = from_bytes(&to_bytes(&a)).unwrap();
        assert_eq!(a, b);
        assert!(b.is_weighted());
    }

    #[test]
    fn v1_payloads_still_load_unweighted() {
        let a = sample();
        let v1 = to_bytes_v1(&a);
        let b = from_bytes(&v1).unwrap();
        assert_eq!(a, b);
        assert!(!b.is_weighted());
        // and the v1 bytes differ from v2 only by the empty weight array
        assert_eq!(to_bytes(&a).len(), v1.len() + 8);
    }

    #[test]
    fn estimated_size_is_exact_here() {
        let a = sample();
        assert_eq!(estimated_bytes(&a), to_bytes(&a).len());
        let w = sample_weighted();
        assert_eq!(estimated_bytes(&w), to_bytes(&w).len());
    }

    #[test]
    fn corrupt_and_truncated_rejected() {
        let bytes = to_bytes(&sample());
        for cut in [0, 5, bytes.len() - 1] {
            assert!(from_bytes(&bytes[..cut]).is_err());
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(from_bytes(&bad).is_err());
    }

    #[test]
    fn unknown_version_rejected() {
        let a = sample();
        let mut payload = Vec::new();
        put_u32(&mut payload, a.lo);
        put_u32(&mut payload, a.hi);
        put_u32s(&mut payload, &a.row_ptr);
        put_u32s(&mut payload, &a.col);
        put_f32s(&mut payload, &a.wgt);
        let bytes = frame(MAGIC, VERSION + 1, &payload);
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard_0000.gms");
        let a = sample_weighted();
        save(&a, &path).unwrap();
        assert_eq!(load(&path).unwrap(), a);
    }

    #[test]
    fn prop_arbitrary_shards_roundtrip() {
        prop::check(0x5A4D, 40, |g| {
            let lo = g.usize_in(0, 100) as u32;
            let width = g.usize_in(1, 64) as u32;
            let m = g.usize_in(0, 300);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    (
                        g.usize_in(0, 1000) as u32,
                        lo + g.usize_in(0, width as usize) as u32,
                    )
                })
                .collect();
            let weighted = g.bool(0.5);
            let weights: Vec<f32> = if weighted {
                (0..m).map(|_| (g.usize_in(1, 16) as f32) * 0.25).collect()
            } else {
                Vec::new()
            };
            let a = Csr::from_edges_weighted(lo, lo + width, &edges, &weights);
            let b = from_bytes(&to_bytes(&a)).unwrap();
            assert_eq!(a, b);
        });
    }
}
