//! Framed binary chunk container used by every GraphMP on-disk file.
//!
//! ```text
//! [4B magic][4B version][8B payload_len][payload...][4B crc32(payload)]
//! ```
//!
//! plus little-endian array helpers for every vertex-value lane
//! (`u32`/`u64`/`f32`/`f64`, see [`crate::graph::value::VertexValue`]) and
//! the lane-tagged [`AnyValues`] vector.
//!
//! ## Format versions
//!
//! The chunk header's `version` field is per-file-type.  Notable bumps:
//!
//! * **shard files (`GMSH`) v1 → v2**: v2 appends the optional per-edge
//!   weight lane (`f32[] wgt`, empty = unweighted) after `col`.  Readers
//!   accept both; v1 shards load as unweighted and reproduce pre-weight
//!   results unchanged (`storage::shardfile`).
//! * **vertex info (`GMVI`) v1 → v2**: v2 stores persisted vertex values as
//!   a lane-tagged [`AnyValues`] array instead of bare `f32[]`
//!   (`storage::vertexinfo`).

use anyhow::{bail, ensure, Result};

use crate::graph::value::{AnyValues, VertexValue};

/// Write a framed chunk.
pub fn frame(magic: &[u8; 4], version: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 20);
    out.extend_from_slice(magic);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let mut crc = crc32fast::Hasher::new();
    crc.update(payload);
    out.extend_from_slice(&crc.finalize().to_le_bytes());
    out
}

/// Parse a framed chunk, returning `(version, payload)`.
pub fn unframe<'a>(magic: &[u8; 4], buf: &'a [u8]) -> Result<(u32, &'a [u8])> {
    ensure!(buf.len() >= 20, "chunk truncated (len {})", buf.len());
    if &buf[0..4] != magic {
        bail!("bad magic {:?} (want {:?})", &buf[0..4], magic);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let len = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    ensure!(
        buf.len() == 20 + len,
        "chunk length mismatch: header {} vs actual {}",
        len,
        buf.len() - 20
    );
    let payload = &buf[16..16 + len];
    let want = u32::from_le_bytes(buf[16 + len..20 + len].try_into().unwrap());
    let mut crc = crc32fast::Hasher::new();
    crc.update(payload);
    ensure!(crc.finalize() == want, "CRC mismatch (corrupt file)");
    Ok((version, payload))
}

// ---- array helpers ---------------------------------------------------------

pub fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn get_u32s(buf: &[u8], pos: usize) -> Result<(Vec<u32>, usize)> {
    ensure!(buf.len() >= pos + 8, "u32 array header truncated");
    let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
    let start = pos + 8;
    ensure!(buf.len() >= start + n * 4, "u32 array payload truncated");
    let v = buf[start..start + n * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((v, start + n * 4))
}

pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn get_f32s(buf: &[u8], pos: usize) -> Result<(Vec<f32>, usize)> {
    ensure!(buf.len() >= pos + 8, "f32 array header truncated");
    let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
    let start = pos + 8;
    ensure!(buf.len() >= start + n * 4, "f32 array payload truncated");
    let v = buf[start..start + n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((v, start + n * 4))
}

pub fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn get_u64s(buf: &[u8], pos: usize) -> Result<(Vec<u64>, usize)> {
    ensure!(buf.len() >= pos + 8, "u64 array header truncated");
    let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
    let start = pos + 8;
    ensure!(buf.len() >= start + n * 8, "u64 array payload truncated");
    let v = buf[start..start + n * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((v, start + n * 8))
}

pub fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn get_f64s(buf: &[u8], pos: usize) -> Result<(Vec<f64>, usize)> {
    ensure!(buf.len() >= pos + 8, "f64 array header truncated");
    let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
    let start = pos + 8;
    ensure!(buf.len() >= start + n * 8, "f64 array payload truncated");
    let v = buf[start..start + n * 8]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((v, start + n * 8))
}

/// Length-prefixed array of any vertex-value lane (the generic counterpart
/// of `put_u32s`/`put_f32s`).
pub fn put_vals<V: VertexValue>(out: &mut Vec<u8>, xs: &[V]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        x.write_le(out);
    }
}

/// Invert [`put_vals`].
pub fn get_vals<V: VertexValue>(buf: &[u8], pos: usize) -> Result<(Vec<V>, usize)> {
    ensure!(buf.len() >= pos + 8, "value array header truncated");
    let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
    let start = pos + 8;
    let nbytes = n
        .checked_mul(V::BYTES)
        .ok_or_else(|| anyhow::anyhow!("value array count overflow"))?;
    ensure!(buf.len() >= start + nbytes, "value array payload truncated");
    let v = buf[start..start + nbytes]
        .chunks_exact(V::BYTES)
        .map(V::read_le)
        .collect();
    Ok((v, start + nbytes))
}

/// Lane-tagged value vector (`[lane u32][count u64][raw]`) — used by the
/// vertex-info v2 payload.
pub fn put_any_values(out: &mut Vec<u8>, vals: &AnyValues) {
    vals.write(out);
}

/// Invert [`put_any_values`].
pub fn get_any_values(buf: &[u8], pos: usize) -> Result<(AnyValues, usize)> {
    AnyValues::read(buf, pos)
}

pub fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub fn get_u64(buf: &[u8], pos: usize) -> Result<(u64, usize)> {
    ensure!(buf.len() >= pos + 8, "u64 truncated");
    Ok((u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()), pos + 8))
}

pub fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub fn get_u32(buf: &[u8], pos: usize) -> Result<(u32, usize)> {
    ensure!(buf.len() >= pos + 4, "u32 truncated");
    Ok((u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()), pos + 4))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello world".to_vec();
        let buf = frame(b"TEST", 3, &payload);
        let (v, p) = unframe(b"TEST", &buf).unwrap();
        assert_eq!(v, 3);
        assert_eq!(p, payload.as_slice());
    }

    #[test]
    fn frame_detects_bitflip_everywhere_in_payload() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let buf = frame(b"TEST", 1, &payload);
        for byte in 16..16 + payload.len() {
            let mut bad = buf.clone();
            bad[byte] ^= 0x01;
            assert!(unframe(b"TEST", &bad).is_err(), "undetected flip at {byte}");
        }
    }

    #[test]
    fn frame_detects_truncation_and_magic() {
        let buf = frame(b"TEST", 1, b"data");
        assert!(unframe(b"TEST", &buf[..buf.len() - 1]).is_err());
        assert!(unframe(b"NOPE", &buf).is_err());
        assert!(unframe(b"TEST", &[]).is_err());
    }

    #[test]
    fn array_helpers_roundtrip() {
        let mut out = Vec::new();
        put_u32s(&mut out, &[1, 2, 3]);
        put_f32s(&mut out, &[1.5, -2.5]);
        put_u64(&mut out, 99);
        put_u32(&mut out, 7);
        let (a, p) = get_u32s(&out, 0).unwrap();
        let (b, p) = get_f32s(&out, p).unwrap();
        let (c, p) = get_u64(&out, p).unwrap();
        let (d, p) = get_u32(&out, p).unwrap();
        assert_eq!(a, vec![1, 2, 3]);
        assert_eq!(b, vec![1.5, -2.5]);
        assert_eq!(c, 99);
        assert_eq!(d, 7);
        assert_eq!(p, out.len());
    }

    #[test]
    fn array_helpers_reject_truncation() {
        let mut out = Vec::new();
        put_u32s(&mut out, &[1, 2, 3]);
        assert!(get_u32s(&out[..out.len() - 1], 0).is_err());
        assert!(get_u32s(&out[..4], 0).is_err());
    }

    #[test]
    fn wide_lane_helpers_roundtrip() {
        let mut out = Vec::new();
        put_u64s(&mut out, &[1, u64::MAX]);
        put_f64s(&mut out, &[-2.5, f64::INFINITY]);
        let (a, p) = get_u64s(&out, 0).unwrap();
        let (b, p) = get_f64s(&out, p).unwrap();
        assert_eq!(a, vec![1, u64::MAX]);
        assert_eq!(b, vec![-2.5, f64::INFINITY]);
        assert_eq!(p, out.len());
        assert!(get_u64s(&out[..out.len() - 1], 8 + 16).is_err());
    }

    #[test]
    fn generic_lane_helpers_roundtrip_all_lanes() {
        fn rt<V: VertexValue>(xs: Vec<V>) {
            let mut out = Vec::new();
            put_vals(&mut out, &xs);
            let (back, p) = get_vals::<V>(&out, 0).unwrap();
            assert_eq!(back, xs);
            assert_eq!(p, out.len());
            if !out.is_empty() {
                assert!(get_vals::<V>(&out[..out.len() - 1], 0).is_err());
            }
        }
        rt(vec![1u32, 2, u32::MAX]);
        rt(vec![7u64, u64::MAX]);
        rt(vec![0.5f32, f32::INFINITY]);
        rt(vec![1.25f64, -0.0]);
    }

    #[test]
    fn any_values_helpers_roundtrip() {
        let vals = AnyValues::U64(vec![3, 2, 1]);
        let mut out = Vec::new();
        put_any_values(&mut out, &vals);
        let (back, p) = get_any_values(&out, 0).unwrap();
        assert_eq!(back, vals);
        assert_eq!(p, out.len());
    }
}
