//! The property file: global metadata of a preprocessed graph (paper §II-B,
//! "a property file contains the global information of the represented
//! graph, including the number of vertices, edges and shards, and the
//! vertex intervals").  Stored as JSON for inspectability.

use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::{GraphInfo, VertexId};
use crate::storage::io;
use crate::util::json::Json;

/// Property file contents.
#[derive(Debug, Clone, PartialEq)]
pub struct Property {
    pub name: String,
    pub info: GraphInfo,
    /// Interval boundaries: shard `i` covers `[intervals[i], intervals[i+1])`.
    /// len = num_shards + 1; first = 0; last = num_vertices.
    pub intervals: Vec<VertexId>,
}

impl Property {
    pub fn num_shards(&self) -> usize {
        self.intervals.len().saturating_sub(1)
    }

    pub fn interval(&self, shard: usize) -> (VertexId, VertexId) {
        (self.intervals[shard], self.intervals[shard + 1])
    }

    /// Which shard's interval contains vertex `v` (binary search over the
    /// boundary array; `v` must be `< num_vertices`).
    pub fn shard_of(&self, v: VertexId) -> usize {
        debug_assert!((v as u64) < self.info.num_vertices);
        match self.intervals.binary_search(&v) {
            Ok(i) => i.min(self.num_shards() - 1),
            Err(i) => i - 1,
        }
    }

    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("num_vertices".into(), Json::Int(self.info.num_vertices as i64));
        m.insert("num_edges".into(), Json::Int(self.info.num_edges as i64));
        m.insert("max_in_degree".into(), Json::Int(self.info.max_in_degree as i64));
        m.insert("max_out_degree".into(), Json::Int(self.info.max_out_degree as i64));
        m.insert(
            "intervals".into(),
            Json::Arr(self.intervals.iter().map(|&v| Json::Int(v as i64)).collect()),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let name = j.req("name")?.as_str().context("name")?.to_string();
        let info = GraphInfo {
            num_vertices: j.req("num_vertices")?.as_i64().context("num_vertices")? as u64,
            num_edges: j.req("num_edges")?.as_i64().context("num_edges")? as u64,
            max_in_degree: j.req("max_in_degree")?.as_i64().context("max_in_degree")? as u32,
            max_out_degree: j.req("max_out_degree")?.as_i64().context("max_out_degree")? as u32,
        };
        let intervals: Vec<VertexId> = j
            .req("intervals")?
            .as_arr()
            .context("intervals")?
            .iter()
            .map(|x| x.as_i64().map(|v| v as VertexId).context("interval"))
            .collect::<Result<_>>()?;
        anyhow::ensure!(intervals.len() >= 2, "need at least one interval");
        anyhow::ensure!(intervals[0] == 0, "intervals must start at 0");
        anyhow::ensure!(
            *intervals.last().unwrap() as u64 == info.num_vertices,
            "intervals must end at num_vertices"
        );
        anyhow::ensure!(intervals.windows(2).all(|w| w[0] < w[1]), "intervals must be increasing");
        Ok(Self { name, info, intervals })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::write_file(path, self.to_json().to_string().as_bytes())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let bytes = io::read_file(path)?;
        let j = Json::parse(std::str::from_utf8(&bytes)?)
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Property {
        Property {
            name: "test".into(),
            info: GraphInfo {
                num_vertices: 100,
                num_edges: 500,
                max_in_degree: 30,
                max_out_degree: 20,
            },
            intervals: vec![0, 40, 100],
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = sample();
        let q = Property::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
        assert_eq!(q.num_shards(), 2);
        assert_eq!(q.interval(1), (40, 100));
    }

    #[test]
    fn shard_of_maps_boundaries_correctly() {
        let p = sample();
        assert_eq!(p.shard_of(0), 0);
        assert_eq!(p.shard_of(39), 0);
        assert_eq!(p.shard_of(40), 1);
        assert_eq!(p.shard_of(99), 1);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_prop_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("property.json");
        let p = sample();
        p.save(&path).unwrap();
        assert_eq!(Property::load(&path).unwrap(), p);
    }

    #[test]
    fn rejects_bad_intervals() {
        let mut p = sample();
        p.intervals = vec![0, 50, 40, 100];
        assert!(Property::from_json(&p.to_json()).is_err());
        p.intervals = vec![5, 100];
        assert!(Property::from_json(&p.to_json()).is_err());
        p.intervals = vec![0, 99];
        assert!(Property::from_json(&p.to_json()).is_err());
    }
}
