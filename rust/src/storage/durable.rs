//! Crash-durable file publication: fsync-then-rename with observable sync
//! counts.
//!
//! A bare `write` + `rename` is *atomic* (a concurrent reader sees the old
//! or the new file, never a torn one) but not *durable*: after a crash the
//! filesystem may replay the rename without the data blocks it points at,
//! leaving a zero-length or garbage target — or lose the rename entirely
//! even though the caller was told the ingest succeeded.  The POSIX recipe
//! for "this file now exists with these bytes, even across power loss" is:
//!
//! 1. write the bytes to a temp file **in the same directory** as the
//!    target (rename must not cross filesystems),
//! 2. `fsync` the temp file (data + inode reach the platter),
//! 3. `rename` it over the target,
//! 4. `fsync` the **parent directory** (the rename itself is a directory
//!    entry update; until the directory's metadata is synced the new name
//!    may vanish on crash).
//!
//! [`write_atomic`] performs all four steps.  [`sync_file`] flushes an
//! already-written artifact before a manifest publishes a reference to it
//! (referenced files must be durable *before* the reference is).
//!
//! Every sync is counted in process-wide counters ([`file_syncs`] /
//! [`dir_syncs`]) so tests can assert the write path really issued them —
//! the [`FsyncSpy`] helper snapshots the counters and reports deltas.
//! Counters are two relaxed atomic increments per publication; the fsyncs
//! themselves dominate by orders of magnitude.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

static FILE_SYNCS: AtomicU64 = AtomicU64::new(0);
static DIR_SYNCS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of file `fsync`s issued through this module.
pub fn file_syncs() -> u64 {
    FILE_SYNCS.load(Ordering::Relaxed)
}

/// Process-wide count of directory `fsync`s issued through this module.
pub fn dir_syncs() -> u64 {
    DIR_SYNCS.load(Ordering::Relaxed)
}

/// Flush an existing file's data and metadata to stable storage.
pub fn sync_file(path: &Path) -> Result<()> {
    let f = File::open(path).with_context(|| format!("opening {} to fsync", path.display()))?;
    f.sync_all().with_context(|| format!("fsync {}", path.display()))?;
    FILE_SYNCS.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Flush a directory's entry table to stable storage — the step that makes
/// a rename (or create) inside it survive a crash.
pub fn sync_dir(dir: &Path) -> Result<()> {
    // opening a directory read-only and calling fsync on it is the portable
    // unix idiom; on platforms where directories cannot be fsynced the
    // open itself fails and we degrade to rename-only atomicity
    match File::open(dir) {
        Ok(d) => {
            d.sync_all().with_context(|| format!("fsync dir {}", dir.display()))?;
            DIR_SYNCS.fetch_add(1, Ordering::Relaxed);
            Ok(())
        }
        Err(e) if e.kind() == std::io::ErrorKind::Unsupported => Ok(()),
        Err(e) => Err(e).with_context(|| format!("opening dir {} to fsync", dir.display())),
    }
}

/// Durably replace `dst` with `bytes`: tmp write → file fsync → rename →
/// parent-directory fsync.  `tmp` must live in the same directory as `dst`.
/// On return, a crash at any point leaves either the complete old file or
/// the complete new file at `dst` — never a missing or torn one.
pub fn write_atomic(tmp: &Path, dst: &Path, bytes: &[u8]) -> Result<()> {
    {
        let mut f = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
        FILE_SYNCS.fetch_add(1, Ordering::Relaxed);
    }
    std::fs::rename(tmp, dst)
        .with_context(|| format!("renaming {} into {}", tmp.display(), dst.display()))?;
    let parent = dst.parent().unwrap_or_else(|| Path::new("."));
    sync_dir(parent)
}

/// Snapshot of the sync counters for test assertions: construct before the
/// code under test, then ask how many syncs it issued.
pub struct FsyncSpy {
    files_before: u64,
    dirs_before: u64,
}

impl FsyncSpy {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { files_before: file_syncs(), dirs_before: dir_syncs() }
    }

    /// (file fsyncs, directory fsyncs) issued since construction.  Counters
    /// are process-wide, so concurrent tests can only *inflate* the deltas;
    /// asserting `>= n` stays sound under parallel test execution.
    pub fn deltas(&self) -> (u64, u64) {
        (
            file_syncs().saturating_sub(self.files_before),
            dir_syncs().saturating_sub(self.dirs_before),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_publishes_bytes_and_syncs_both_levels() {
        let dir = std::env::temp_dir().join(format!("gmp_durable_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dst = dir.join("target.json");
        let tmp = dir.join(".target.json.tmp");
        let spy = FsyncSpy::new();
        write_atomic(&tmp, &dst, b"{\"v\":1}\n").unwrap();
        let (files, dirs) = spy.deltas();
        assert!(files >= 1, "tmp file must be fsynced before the rename");
        assert!(dirs >= 1, "parent dir must be fsynced after the rename");
        assert!(!tmp.exists(), "tmp must be renamed away");
        assert_eq!(std::fs::read(&dst).unwrap(), b"{\"v\":1}\n");
        // overwrite goes through the same path
        write_atomic(&tmp, &dst, b"{\"v\":2}\n").unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), b"{\"v\":2}\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sync_file_counts_and_errors_on_missing() {
        let dir = std::env::temp_dir().join(format!("gmp_durable_sf_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("artifact.bin");
        std::fs::write(&p, b"abc").unwrap();
        let spy = FsyncSpy::new();
        sync_file(&p).unwrap();
        assert!(spy.deltas().0 >= 1);
        assert!(sync_file(&dir.join("nope.bin")).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
