//! `artifacts/manifest.json` parsing + geometry validation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::geometry::Geometry;
use crate::util::json::Json;

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<String>,
}

/// Parsed manifest: geometry + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: i64,
    pub geometry: Geometry,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json` and verify every referenced artifact exists.
    pub fn load(dir: &Path) -> Result<Self> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", mpath.display()))?;

        let version = j.req("version")?.as_i64().context("version must be int")?;
        let g = j.req("geometry")?;
        let geometry = Geometry {
            v_max: g.req("v_max")?.as_i64().context("v_max")? as usize,
            e_max: g.req("e_max")?.as_i64().context("e_max")? as usize,
            tile_e: g.req("tile_e")?.as_i64().context("tile_e")? as usize,
        };

        let mut artifacts = BTreeMap::new();
        for (name, entry) in j.req("artifacts")?.as_obj().context("artifacts must be object")? {
            let file = entry.req("file")?.as_str().context("file must be str")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact {name} missing on disk: {}", path.display());
            }
            let inputs = entry
                .get("inputs")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactEntry { name: name.clone(), path, inputs },
            );
        }
        Ok(Self { version, geometry, artifacts, dir: dir.to_path_buf() })
    }

    /// Fail unless the manifest geometry matches the crate's compiled-in
    /// constants (a stale `artifacts/` dir would silently mis-pad shards).
    pub fn check_geometry(&self) -> Result<()> {
        if self.geometry != Geometry::NATIVE {
            bail!(
                "artifact geometry {:?} != crate geometry {:?}; re-run `make artifacts`",
                self.geometry,
                Geometry::NATIVE
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, v_max: i64) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        write!(
            f,
            r#"{{"version":1,
               "geometry":{{"v_max":{v_max},"e_max":16384,"tile_e":1024}},
               "artifacts":{{"pr_shard":{{"file":"pr_shard.hlo.txt","inputs":["a"]}}}}}}"#
        )
        .unwrap();
        std::fs::write(dir.join("pr_shard.hlo.txt"), "HloModule x").unwrap();
    }

    #[test]
    fn load_and_validate() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 2048);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 1);
        assert!(m.check_geometry().is_ok());
        assert!(m.artifacts.contains_key("pr_shard"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 999);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.check_geometry().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_miss_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 2048);
        std::fs::remove_file(dir.join("pr_shard.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
