//! Manifests: the `artifacts/manifest.json` AOT-artifact table (geometry
//! validation) and the dataset-side **epoch manifest** (`epochs.json`)
//! that versions a mutable graph.
//!
//! The epoch manifest is the snapshot spine of the dynamic-graph
//! subsystem: every applied mutation batch (`graphmp ingest`) and every
//! compaction (`graphmp compact`) appends one immutable [`Epoch`] whose
//! per-shard file table names exactly which base shard / delta shard /
//! Bloom filter a reader at that epoch sees.  Files referenced by older
//! epochs are never rewritten, so any historical epoch reproduces
//! bit-for-bit; the manifest itself is replaced atomically (tmp + rename)
//! so a reader always loads a consistent snapshot.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::geometry::Geometry;
use crate::storage::property::Property;
use crate::storage::DatasetDir;
use crate::util::json::Json;

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<String>,
}

/// Parsed manifest: geometry + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: i64,
    pub geometry: Geometry,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json` and verify every referenced artifact exists.
    pub fn load(dir: &Path) -> Result<Self> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", mpath.display()))?;

        let version = j.req("version")?.as_i64().context("version must be int")?;
        let g = j.req("geometry")?;
        let geometry = Geometry {
            v_max: g.req("v_max")?.as_i64().context("v_max")? as usize,
            e_max: g.req("e_max")?.as_i64().context("e_max")? as usize,
            tile_e: g.req("tile_e")?.as_i64().context("tile_e")? as usize,
        };

        let mut artifacts = BTreeMap::new();
        for (name, entry) in j.req("artifacts")?.as_obj().context("artifacts must be object")? {
            let file = entry.req("file")?.as_str().context("file must be str")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact {name} missing on disk: {}", path.display());
            }
            let inputs = entry
                .get("inputs")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactEntry { name: name.clone(), path, inputs },
            );
        }
        Ok(Self { version, geometry, artifacts, dir: dir.to_path_buf() })
    }

    /// Fail unless the manifest geometry matches the crate's compiled-in
    /// constants (a stale `artifacts/` dir would silently mis-pad shards).
    pub fn check_geometry(&self) -> Result<()> {
        if self.geometry != Geometry::NATIVE {
            bail!(
                "artifact geometry {:?} != crate geometry {:?}; re-run `make artifacts`",
                self.geometry,
                Geometry::NATIVE
            );
        }
        Ok(())
    }
}

// ---- epoch manifest (dynamic-graph snapshots) -------------------------------

/// Manifest entries store file *names* relative to the dataset root; the
/// names come from [`DatasetDir`]'s path helpers so the on-disk scheme has
/// one source of truth.
pub(crate) fn rel_name(path: &Path) -> String {
    path.file_name()
        .expect("dataset artifact paths always carry a file name")
        .to_string_lossy()
        .into_owned()
}

/// What one shard looks like at a given epoch: its (possibly compacted)
/// base shard file, the Bloom filter covering the *merged* sources, and the
/// resident delta file if the shard has un-compacted mutations.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochShard {
    pub shard: String,
    pub bloom: String,
    pub delta: Option<String>,
    /// Epoch id at which `shard` (the base file) was last rewritten — the
    /// cache's slot-invalidation key: ingest leaves it unchanged (base
    /// bytes are untouched, residents stay valid), compaction bumps it.
    pub shard_epoch: u64,
}

/// One immutable snapshot of the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Epoch {
    pub id: u64,
    /// `"base"` (preprocessing output), `"ingest"` or `"compact"`.
    pub kind: String,
    pub parent: Option<u64>,
    /// Live edges at this epoch (base − tombstoned + inserted).
    pub num_edges: u64,
    /// Vertex-info file carrying this epoch's degree arrays.
    pub vertexinfo: String,
    /// Archived mutation log applied by this epoch (`ingest` only).
    pub batch: Option<String>,
    pub inserts: u64,
    pub deletes: u64,
    pub shards: Vec<EpochShard>,
}

/// The `epochs.json` snapshot chain of a mutable dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochManifest {
    pub version: i64,
    /// Epoch readers open by default (always the last entry's id).
    pub current: u64,
    pub epochs: Vec<Epoch>,
}

impl EpochManifest {
    /// The base epoch of a freshly preprocessed (static) dataset: the
    /// preprocessing output's standard file names, no deltas.
    pub fn bootstrap(property: &Property) -> Self {
        // names only — the rootless DatasetDir is just the naming scheme
        let names = DatasetDir::new("");
        let shards = (0..property.num_shards())
            .map(|i| EpochShard {
                shard: rel_name(&names.shard_path(i)),
                bloom: rel_name(&names.bloom_path(i)),
                delta: None,
                shard_epoch: 0,
            })
            .collect();
        EpochManifest {
            version: 1,
            current: 0,
            epochs: vec![Epoch {
                id: 0,
                kind: "base".into(),
                parent: None,
                num_edges: property.info.num_edges,
                vertexinfo: rel_name(&names.vertexinfo_path()),
                batch: None,
                inserts: 0,
                deletes: 0,
                shards,
            }],
        }
    }

    /// Load `dir/epochs.json`, or synthesize the base epoch when the
    /// dataset has never been mutated.
    pub fn load_or_bootstrap(dir: &DatasetDir, property: &Property) -> Result<Self> {
        let path = dir.epochs_path();
        if path.exists() {
            Self::load(&path)
        } else {
            Ok(Self::bootstrap(property))
        }
    }

    pub fn latest(&self) -> &Epoch {
        self.epochs.last().expect("manifest always holds >= 1 epoch")
    }

    pub fn epoch(&self, id: u64) -> Result<&Epoch> {
        self.epochs
            .iter()
            .find(|e| e.id == id)
            .with_context(|| format!("epoch {id} not in manifest (current {})", self.current))
    }

    /// Epochs strictly after `from` up to and including `to`, oldest first.
    pub fn epochs_between(&self, from: u64, to: u64) -> Vec<&Epoch> {
        self.epochs.iter().filter(|e| e.id > from && e.id <= to).collect()
    }

    pub fn to_json(&self) -> Json {
        let epochs = self
            .epochs
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("id".into(), Json::Int(e.id as i64));
                m.insert("kind".into(), Json::Str(e.kind.clone()));
                if let Some(p) = e.parent {
                    m.insert("parent".into(), Json::Int(p as i64));
                }
                m.insert("num_edges".into(), Json::Int(e.num_edges as i64));
                m.insert("vertexinfo".into(), Json::Str(e.vertexinfo.clone()));
                if let Some(b) = &e.batch {
                    m.insert("batch".into(), Json::Str(b.clone()));
                }
                m.insert("inserts".into(), Json::Int(e.inserts as i64));
                m.insert("deletes".into(), Json::Int(e.deletes as i64));
                let shards = e
                    .shards
                    .iter()
                    .map(|s| {
                        let mut sm = BTreeMap::new();
                        sm.insert("shard".into(), Json::Str(s.shard.clone()));
                        sm.insert("bloom".into(), Json::Str(s.bloom.clone()));
                        if let Some(d) = &s.delta {
                            sm.insert("delta".into(), Json::Str(d.clone()));
                        }
                        sm.insert("shard_epoch".into(), Json::Int(s.shard_epoch as i64));
                        Json::Obj(sm)
                    })
                    .collect();
                m.insert("shards".into(), Json::Arr(shards));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("version".into(), Json::Int(self.version));
        root.insert("current".into(), Json::Int(self.current as i64));
        root.insert("epochs".into(), Json::Arr(epochs));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j.req("version")?.as_i64().context("version")?;
        let current = j.req("current")?.as_i64().context("current")? as u64;
        let mut epochs = Vec::new();
        for e in j.req("epochs")?.as_arr().context("epochs must be array")? {
            let mut shards = Vec::new();
            for s in e.req("shards")?.as_arr().context("shards must be array")? {
                shards.push(EpochShard {
                    shard: s.req("shard")?.as_str().context("shard")?.to_string(),
                    bloom: s.req("bloom")?.as_str().context("bloom")?.to_string(),
                    delta: s.get("delta").and_then(|d| d.as_str()).map(String::from),
                    shard_epoch: s
                        .get("shard_epoch")
                        .and_then(Json::as_i64)
                        .unwrap_or(0) as u64,
                });
            }
            epochs.push(Epoch {
                id: e.req("id")?.as_i64().context("id")? as u64,
                kind: e.req("kind")?.as_str().context("kind")?.to_string(),
                parent: e.get("parent").and_then(Json::as_i64).map(|p| p as u64),
                num_edges: e.req("num_edges")?.as_i64().context("num_edges")? as u64,
                vertexinfo: e.req("vertexinfo")?.as_str().context("vertexinfo")?.to_string(),
                batch: e.get("batch").and_then(|b| b.as_str()).map(String::from),
                inserts: e.get("inserts").and_then(Json::as_i64).unwrap_or(0) as u64,
                deletes: e.get("deletes").and_then(Json::as_i64).unwrap_or(0) as u64,
                shards,
            });
        }
        anyhow::ensure!(!epochs.is_empty(), "epoch manifest holds no epochs");
        anyhow::ensure!(
            epochs.windows(2).all(|w| w[0].id < w[1].id),
            "epoch ids must be increasing"
        );
        anyhow::ensure!(
            epochs.last().unwrap().id == current,
            "current epoch must be the last entry"
        );
        let p = epochs[0].shards.len();
        anyhow::ensure!(
            epochs.iter().all(|e| e.shards.len() == p),
            "epoch shard tables disagree on shard count"
        );
        Ok(Self { version, current, epochs })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?)
    }

    /// Durably replace `dir/epochs.json` via
    /// [`crate::storage::durable::write_atomic`]: tmp write → file fsync →
    /// rename → parent-directory fsync.  A concurrent reader sees either
    /// the previous snapshot chain or the new one (never a torn file), and
    /// a crash at any point cannot lose the manifest every historical
    /// epoch depends on.  Callers must fsync any artifacts a new epoch
    /// references *before* calling this — publication makes them reachable.
    pub fn save(&self, dir: &DatasetDir) -> Result<()> {
        let path = dir.epochs_path();
        let tmp = dir.root.join(".epochs.json.tmp");
        crate::storage::durable::write_atomic(
            &tmp,
            &path,
            format!("{}\n", self.to_json()).as_bytes(),
        )
        .with_context(|| format!("publishing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, v_max: i64) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        write!(
            f,
            r#"{{"version":1,
               "geometry":{{"v_max":{v_max},"e_max":16384,"tile_e":1024}},
               "artifacts":{{"pr_shard":{{"file":"pr_shard.hlo.txt","inputs":["a"]}}}}}}"#
        )
        .unwrap();
        std::fs::write(dir.join("pr_shard.hlo.txt"), "HloModule x").unwrap();
    }

    #[test]
    fn load_and_validate() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 2048);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 1);
        assert!(m.check_geometry().is_ok());
        assert!(m.artifacts.contains_key("pr_shard"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 999);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.check_geometry().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = std::env::temp_dir().join(format!("gmp_manifest_miss_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 2048);
        std::fs::remove_file(dir.join("pr_shard.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    // ---- epoch manifest ----------------------------------------------------

    fn sample_property() -> Property {
        Property {
            name: "t".into(),
            info: crate::graph::GraphInfo {
                num_vertices: 20,
                num_edges: 9,
                max_in_degree: 3,
                max_out_degree: 3,
            },
            intervals: vec![0, 10, 20],
        }
    }

    #[test]
    fn epoch_manifest_bootstrap_and_roundtrip() {
        let p = sample_property();
        let mut m = EpochManifest::bootstrap(&p);
        assert_eq!(m.current, 0);
        assert_eq!(m.latest().shards.len(), 2);
        assert_eq!(m.latest().num_edges, 9);
        // append an ingest epoch touching shard 1
        let mut e1 = m.latest().clone();
        e1.id = 1;
        e1.kind = "ingest".into();
        e1.parent = Some(0);
        e1.num_edges = 11;
        e1.inserts = 2;
        e1.vertexinfo = "vertexinfo_e0001.bin".into();
        e1.batch = Some("batch_e0001.gmdl".into());
        e1.shards[1].delta = Some("delta_0001_e0001.gmd".into());
        e1.shards[1].bloom = "bloom_0001_e0001.gmb".into();
        m.epochs.push(e1);
        m.current = 1;
        let n = EpochManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, n);
        assert_eq!(n.epoch(1).unwrap().shards[1].delta.as_deref(), Some("delta_0001_e0001.gmd"));
        assert!(n.epoch(7).is_err());
        assert_eq!(n.epochs_between(0, 1).len(), 1);
        assert!(n.epochs_between(1, 1).is_empty());
    }

    #[test]
    fn epoch_manifest_save_is_atomic_and_loadable() {
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_epochs_{}", std::process::id())),
        );
        dir.create().unwrap();
        let p = sample_property();
        let m = EpochManifest::bootstrap(&p);
        m.save(&dir).unwrap();
        assert!(dir.epochs_path().exists());
        assert!(!dir.root.join(".epochs.json.tmp").exists(), "tmp file must be renamed away");
        assert_eq!(EpochManifest::load(&dir.epochs_path()).unwrap(), m);
        // load_or_bootstrap prefers the on-disk chain
        assert_eq!(EpochManifest::load_or_bootstrap(&dir, &p).unwrap(), m);
        std::fs::remove_dir_all(&dir.root).unwrap();
    }

    #[test]
    fn epoch_manifest_save_fsyncs_file_and_directory() {
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_epochs_sync_{}", std::process::id())),
        );
        dir.create().unwrap();
        let m = EpochManifest::bootstrap(&sample_property());
        let spy = crate::storage::durable::FsyncSpy::new();
        m.save(&dir).unwrap();
        let (files, dirs) = spy.deltas();
        assert!(files >= 1, "manifest tmp file must be fsynced before rename (saw {files})");
        assert!(dirs >= 1, "dataset dir must be fsynced after rename (saw {dirs})");
        assert_eq!(EpochManifest::load(&dir.epochs_path()).unwrap(), m);
        std::fs::remove_dir_all(&dir.root).unwrap();
    }

    #[test]
    fn epoch_manifest_rejects_inconsistent_chains() {
        let p = sample_property();
        let mut m = EpochManifest::bootstrap(&p);
        m.current = 3; // current must match the last entry
        assert!(EpochManifest::from_json(&m.to_json()).is_err());
        let mut m = EpochManifest::bootstrap(&p);
        let mut dup = m.latest().clone();
        dup.shards.pop(); // shard-count drift
        dup.id = 1;
        m.epochs.push(dup);
        m.current = 1;
        assert!(EpochManifest::from_json(&m.to_json()).is_err());
    }
}
