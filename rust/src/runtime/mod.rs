//! PJRT runtime: load the AOT-compiled HLO artifacts once, execute them from
//! the engine hot path.  Python is never invoked here — the artifacts under
//! `artifacts/` are self-contained HLO text produced at build time by
//! `python/compile/aot.py`.
//!
//! ```text
//! manifest.json ──► Manifest (geometry + artifact names)
//! *.hlo.txt     ──► HloModuleProto::from_text_file ─► compile ─► executable
//! shard data    ──► pad to geometry ─► execute ─► unpad
//! ```

mod executor;
pub mod geometry;
mod manifest;

pub use executor::ShardRuntime;
pub use geometry::Geometry;
pub use manifest::{Epoch, EpochManifest, EpochShard, Manifest};
pub(crate) use manifest::rel_name;
