//! `ShardRuntime`: compile the HLO artifacts once on the PJRT CPU client and
//! expose typed shard-update entry points to the engine.
//!
//! Execution contract (see `python/compile/model.py`):
//!
//! * inputs are padded to the manifest geometry — `contrib` with the
//!   reduction identity (0 for sum, +inf for min), `dst` with 0;
//! * outputs come back as a 1-tuple (`return_tuple=True` at lowering) of a
//!   `f32[V_MAX]` literal which is truncated to the shard's real vertex
//!   count.
//!
//! # Thread safety
//!
//! The `xla` crate's client/executable handles are `Rc`-based and not
//! `Send`/`Sync`.  The engine's worker threads all need to invoke kernels,
//! so every touch of an xla object (compile, literal upload via execute,
//! result fetch) happens under the single `inner` mutex; nothing `Rc`-backed
//! ever escapes it.  Under that discipline cross-thread use is sound, hence
//! the `unsafe impl`s below.  The CPU PJRT plugin largely serializes
//! execution internally anyway, so the lock costs little.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::geometry::Geometry;
use super::manifest::Manifest;

/// Identity element padding for sum-reductions.
pub const PAD_SUM: f32 = 0.0;
/// Identity element padding for min-reductions.
pub const PAD_MIN: f32 = f32::INFINITY;

struct Inner {
    #[allow(dead_code)] // owns the PJRT client the executables refer to
    client: xla::PjRtClient,
    kernels: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// Runtime holding the PJRT client + all compiled shard kernels.
pub struct ShardRuntime {
    inner: Mutex<Inner>,
    pub geometry: Geometry,
    /// Number of kernel invocations (for perf accounting).
    calls: AtomicU64,
}

// SAFETY: all xla::* objects live inside `inner` and are only manipulated
// while holding that mutex (see module docs); the Rc refcounts they contain
// are therefore never touched concurrently.
unsafe impl Send for ShardRuntime {}
unsafe impl Sync for ShardRuntime {}

impl ShardRuntime {
    /// Load + compile every artifact in `artifact_dir`.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        manifest.check_geometry()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut kernels = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().context("artifact path utf8")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            kernels.insert(name.clone(), exe);
        }
        Ok(Self {
            inner: Mutex::new(Inner { client, kernels }),
            geometry: manifest.geometry,
            calls: AtomicU64::new(0),
        })
    }

    /// Kernel invocation count since load.
    pub fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn has_kernel(&self, name: &str) -> bool {
        self.inner.lock().unwrap().kernels.contains_key(name)
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let inner = self.inner.lock().unwrap();
        let exe = inner
            .kernels
            .get(name)
            .ok_or_else(|| anyhow!("kernel {name} not in manifest"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        drop(inner);
        self.calls.fetch_add(1, Ordering::Relaxed);
        // Lowered with return_tuple=True => 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Pad `contrib`/`dst` to geometry. Panics if the shard exceeds capacity
    /// (the sharder guarantees it never does).
    fn pad_edges(&self, contrib: &[f32], dst: &[u32], identity: f32) -> (Vec<f32>, Vec<i32>) {
        let g = &self.geometry;
        assert!(
            contrib.len() <= g.e_max && contrib.len() == dst.len(),
            "shard edges {} exceed kernel capacity {}",
            contrib.len(),
            g.e_max
        );
        let mut c = Vec::with_capacity(g.e_max);
        c.extend_from_slice(contrib);
        c.resize(g.e_max, identity);
        let mut d = Vec::with_capacity(g.e_max);
        d.extend(dst.iter().map(|&x| x as i32));
        d.resize(g.e_max, 0);
        (c, d)
    }

    /// PageRank shard update: `new[v] = 0.15/n + 0.85 * Σ contrib[e]` over
    /// edges with `dst[e] == v`.  Returns the first `n_vertices` lanes.
    pub fn pr_shard(
        &self,
        contrib: &[f32],
        dst: &[u32],
        inv_n: f32,
        n_vertices: usize,
    ) -> Result<Vec<f32>> {
        let (c, d) = self.pad_edges(contrib, dst, PAD_SUM);
        let args = [
            xla::Literal::vec1(&c),
            xla::Literal::vec1(&d),
            xla::Literal::vec1(&[inv_n]),
        ];
        let mut out = self.run("pr_shard", &args)?;
        out.truncate(n_vertices);
        Ok(out)
    }

    /// SSSP/WCC shard update: `new[v] = min(old[v], min contrib[e])`.
    pub fn relaxmin_shard(
        &self,
        contrib: &[f32],
        dst: &[u32],
        old: &[f32],
        n_vertices: usize,
    ) -> Result<Vec<f32>> {
        let g = &self.geometry;
        assert!(old.len() <= g.v_max && n_vertices <= old.len());
        let (c, d) = self.pad_edges(contrib, dst, PAD_MIN);
        let mut o = Vec::with_capacity(g.v_max);
        o.extend_from_slice(old);
        o.resize(g.v_max, PAD_MIN);
        let args = [
            xla::Literal::vec1(&c),
            xla::Literal::vec1(&d),
            xla::Literal::vec1(&o),
        ];
        let mut out = self.run("relaxmin_shard", &args)?;
        out.truncate(n_vertices);
        Ok(out)
    }

    /// Raw segmented sum (generic SpMV building block).
    pub fn segsum_shard(
        &self,
        contrib: &[f32],
        dst: &[u32],
        n_vertices: usize,
    ) -> Result<Vec<f32>> {
        let (c, d) = self.pad_edges(contrib, dst, PAD_SUM);
        let args = [xla::Literal::vec1(&c), xla::Literal::vec1(&d)];
        let mut out = self.run("segsum_shard", &args)?;
        out.truncate(n_vertices);
        Ok(out)
    }
}
