//! Kernel geometry: the fixed shapes every AOT artifact was lowered with.
//!
//! These constants must match `python/compile/kernels/segsum.py`; the
//! manifest loader enforces the match at startup so a stale `artifacts/`
//! directory fails fast instead of producing shape errors mid-run.

/// Padded vertices per shard interval (f32 output lane count).
pub const V_MAX: usize = 2048;
/// Padded edges per shard (contrib/dst lane count).
pub const E_MAX: usize = 16384;
/// Edges per Pallas grid step.
pub const TILE_E: usize = 1024;

/// Geometry triple as read from a manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub v_max: usize,
    pub e_max: usize,
    pub tile_e: usize,
}

impl Geometry {
    /// The geometry this crate was compiled against.
    pub const NATIVE: Geometry = Geometry { v_max: V_MAX, e_max: E_MAX, tile_e: TILE_E };

    /// Max real (unpadded) edges a single kernel call can carry.
    pub fn edge_capacity(&self) -> usize {
        self.e_max
    }

    /// Max real vertices a single kernel call can cover.
    pub fn vertex_capacity(&self) -> usize {
        self.v_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_geometry_is_consistent() {
        let g = Geometry::NATIVE;
        assert_eq!(g.e_max % g.tile_e, 0, "edges must tile evenly");
        assert!(g.v_max > 0 && g.e_max > 0);
    }
}
