//! PSW — the parallel sliding window model of **GraphChi** (Kyrola et al.,
//! OSDI'12), as analyzed in paper §III-A.
//!
//! GraphChi stores vertex values *on the edges*: each shard holds the
//! interval's in-edges (sorted by source) together with a per-edge value
//! slot carrying the source's latest value.  Executing a shard:
//!
//! 1. load its vertices, in-edges and out-edge windows — read
//!    `C·V + 2(C+D)·E` per iteration in total;
//! 2. update vertex values from the edge values;
//! 3. write vertices and both edge directions back — `C·V + 2(C+D)·E`.
//!
//! Here the in-edge structure (CSR, with the optional weight lane) and the
//! edge-value files are real disk files, re-read and re-written every
//! iteration.  The *out-edge window* traffic (GraphChi's P sliding windows
//! that update source values in the other shards) touches the same bytes a
//! second time; we refresh the edge values from the new vertex array in one
//! pass and account the second direction via `account_virtual_*`, keeping
//! the measured volume equal to the model's.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::baselines::common::{self, BaselineRun, OocEngine};
use crate::graph::csr::Csr;
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::sharding::intervals::compute_intervals;
use crate::storage::prefetch::ReadAhead;
use crate::storage::{io, shardfile};

/// Edges per shard (the paper's GraphChi config uses millions; scaled).
const EDGES_PER_SHARD: usize = 1 << 14;

pub struct PswEngine {
    dir: PathBuf,
    intervals: Vec<VertexId>,
    num_vertices: usize,
    num_edges: u64,
    out_deg: Vec<u32>,
    adaptive_order: bool,
}

impl PswEngine {
    pub fn new(dir: PathBuf) -> Self {
        Self {
            dir,
            intervals: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            out_deg: Vec::new(),
            adaptive_order: false,
        }
    }

    /// Issue shards hottest-first (previous iteration's changed-vertex
    /// counts) instead of in file order — same files, same bytes, same
    /// per-shard fold order, so results are identical either way.
    pub fn set_adaptive_order(&mut self, on: bool) {
        self.adaptive_order = on;
    }

    fn shard_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("psw_shard_{i:04}.bin"))
    }

    fn evals_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("psw_evals_{i:04}.bin"))
    }

    fn values_path(&self) -> PathBuf {
        self.dir.join("psw_values.bin")
    }

    fn num_shards(&self) -> usize {
        self.intervals.len().saturating_sub(1)
    }

    /// Memory model with an explicit lane width `c` (the paper's C; 4 for
    /// the f32 case): one shard's subgraph — (C·V + 2(C+D)·E)/P.
    fn memory_estimate_lane(&self, c: u64) -> u64 {
        let p = self.num_shards().max(1) as u64;
        (c * self.num_vertices as u64 + 2 * (c + 8) * self.num_edges) / p
    }

    /// Typed run over any value lane (see trait docs).
    pub fn run_typed<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let n = self.num_vertices;
        let p = self.num_shards();
        let ctx = ProgramContext { num_vertices: n as u64 };
        let t0 = Instant::now();

        // initialize the on-disk vertex value file and edge values
        let init: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        common::write_values(&self.values_path(), &init)?;
        for i in 0..p {
            let csr = shardfile::load(&self.shard_path(i))?;
            let evals: Vec<V> = csr.col.iter().map(|&u| init[u as usize]).collect();
            common::write_values(&self.evals_path(i), &evals)?;
        }
        let load_wall = t0.elapsed();

        let io_start = io::snapshot();
        let mut iter_walls = Vec::new();
        let mut iter_io = Vec::new();
        let mut edges_processed = 0u64;
        let mut sched = common::HeatSchedule::new(p, self.adaptive_order);

        for _iter in 0..max_iters {
            let t_iter = Instant::now();
            let io_before = io::snapshot();

            // step 1 reads: the iteration's vertex value file (C·V)
            let values: Vec<V> = common::read_values(&self.values_path())?;
            let mut new_values = values.clone();
            let mut changed = false;

            // shard + edge-value files stream through an ordered read-ahead
            // (hottest-first under adaptive order): same files, same byte
            // accounting — the next shard's disk time just overlaps the
            // current shard's update, and each shard writes only its own
            // interval from the previous values, so order never changes
            // results
            let order = sched.order();
            let mut stream = ReadAhead::new(
                order
                    .iter()
                    .flat_map(|&i| [self.shard_path(i), self.evals_path(i)])
                    .collect(),
                common::READ_AHEAD_DEPTH,
            );
            for &i in &order {
                // D·E/P real
                let csr = shardfile::from_bytes(&common::next_buf(&mut stream, "psw shard")?)?;
                // C·E/P real
                let evals: Vec<V> =
                    common::values_from_bytes(&common::next_buf(&mut stream, "psw evals")?)?;
                // out-edge sliding-window pass reads the same bytes again:
                // C+D per edge with C = the lane width (the paper's C=4 is
                // the f32 case)
                io::account_virtual_read((csr.num_edges() * (V::BYTES + 8)) as u64);
                let (lo, _hi) = (csr.lo, csr.hi);
                let mut shard_changed = 0u64;
                for (row, (v, _)) in csr.iter_rows().enumerate() {
                    let s = csr.row_ptr[row] as usize;
                    let e = csr.row_ptr[row + 1] as usize;
                    let reduce = app.reduce();
                    let mut acc = reduce.identity();
                    for k in s..e {
                        let src = csr.col[k];
                        // GraphChi semantics: the source value comes off the
                        // edge, not a vertex array
                        acc = reduce.combine(
                            acc,
                            app.gather(evals[k], self.out_deg[src as usize], csr.weight(k)),
                        );
                    }
                    let old = values[v as usize];
                    let nv = app.apply(acc, old, &ctx);
                    if V::changed(old, nv, 0.0) {
                        changed = true;
                        shard_changed += 1;
                    }
                    new_values[(lo + row as u32) as usize] = nv;
                }
                sched.record(i, shard_changed);
                edges_processed += csr.num_edges() as u64;
            }

            // step 3 writes: vertices (C·V) + both edge directions
            // (2(C+D)·E = 24 B/edge). The real write below covers the value
            // half of direction 1 (C = 4 B/edge); the remaining 20 B/edge
            // (direction-1 structure + all of direction 2, which GraphChi
            // rewrites through its sliding windows) is accounted virtually.
            common::write_values(&self.values_path(), &new_values)?;
            let mut stream = ReadAhead::new(
                order.iter().map(|&i| self.shard_path(i)).collect(),
                common::READ_AHEAD_DEPTH,
            );
            for &i in &order {
                let csr =
                    shardfile::from_bytes(&common::next_buf(&mut stream, "psw writeback")?)?;
                let evals: Vec<V> =
                    csr.col.iter().map(|&u| new_values[u as usize]).collect();
                common::write_values(&self.evals_path(i), &evals)?;
                // direction-1 structure (D=8) + all of direction 2 (C+D),
                // lane-width aware (f32 reproduces the paper's 20 B/edge)
                io::account_virtual_write((csr.num_edges() * (V::BYTES + 16)) as u64);
            }

            sched.advance();
            iter_walls.push(t_iter.elapsed());
            iter_io.push(io::snapshot().since(&io_before));
            if !changed {
                break;
            }
        }

        let values: Vec<V> = common::read_values(&self.values_path())?;
        Ok(BaselineRun {
            values,
            iter_walls,
            load_wall,
            total_wall: t0.elapsed(),
            io: io::snapshot().since(&io_start),
            iter_io,
            memory_bytes: self.memory_estimate_lane(V::BYTES as u64),
            edges_processed,
        })
    }
}

impl OocEngine for PswEngine {
    fn name(&self) -> &'static str {
        "psw(graphchi)"
    }

    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()> {
        common::fresh_dir(&self.dir)?;
        let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
        self.out_deg = degrees.out_deg.clone();
        self.intervals = compute_intervals(&degrees.in_deg, EDGES_PER_SHARD);
        self.num_vertices = num_vertices;
        self.num_edges = edges.len() as u64;
        let p = self.num_shards();
        let (buckets, wbuckets) =
            common::bucket_weighted(&self.intervals, p, edges, weights, |(_, d)| d);
        for (i, bucket) in buckets.iter().enumerate() {
            let csr = Csr::from_edges_weighted(
                self.intervals[i],
                self.intervals[i + 1],
                bucket,
                &wbuckets[i],
            );
            shardfile::save(&csr, &self.shard_path(i))?;
            // edge-value slots start at 0 (re-filled from init at run start)
            common::write_values(&self.evals_path(i), &vec![0.0f32; csr.num_edges()])?;
        }
        Ok(())
    }

    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun> {
        self.run_typed(app, max_iters)
    }

    /// GraphChi keeps one shard's subgraph in memory: |V|/P vertices and
    /// their in/out edges — (C·V + 2(C+D)·E)/P with the f32 lane's C=4.
    fn memory_estimate(&self) -> u64 {
        self.memory_estimate_lane(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, WeightedSssp};
    use crate::graph::generator;

    #[test]
    fn psw_pagerank_converges_like_reference() {
        let edges = generator::erdos_renyi(100, 600, 7);
        let mut eng = PswEngine::new(
            std::env::temp_dir().join(format!("gmp_psw_t_{}", std::process::id())),
        );
        eng.prepare(&edges, 100).unwrap();
        let run = eng.run(&PageRank::default(), 5).unwrap();
        assert_eq!(run.values.len(), 100);
        // compare against the plain reference
        let ctx = ProgramContext { num_vertices: 100 };
        let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); 100];
        let mut out_deg = vec![0u32; 100];
        for &(s, d) in &edges {
            in_adj[d as usize].push(s);
            out_deg[s as usize] += 1;
        }
        let app = PageRank::default();
        let mut vals: Vec<f32> = (0..100).map(|v| app.init(v, &ctx)).collect();
        for _ in 0..5 {
            vals = (0..100u32)
                .map(|v| app.update(v, &in_adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
        }
        for (i, (a, b)) in run.values.iter().zip(&vals).enumerate() {
            assert!((a - b).abs() < 1e-5, "v{i}: {a} vs {b}");
        }
        // Table II shape: writes ≈ reads (PSW writes edges back both ways)
        assert!(run.io.bytes_written as f64 > 0.5 * run.io.bytes_read as f64);
    }

    #[test]
    fn psw_weighted_sssp_relaxes_through_edge_values() {
        // weighted path 0 -(0.5)-> 1 -(0.25)-> 2 plus a heavy shortcut
        let edges = vec![(0u32, 1u32), (1, 2), (0, 2)];
        let weights = vec![0.5f32, 0.25, 5.0];
        let mut eng = PswEngine::new(
            std::env::temp_dir().join(format!("gmp_psw_w_{}", std::process::id())),
        );
        eng.prepare_weighted(&edges, &weights, 3).unwrap();
        let run = eng.run_typed(&WeightedSssp { source: 0 }, 50).unwrap();
        assert_eq!(run.values, vec![0.0, 0.5, 0.75]);
    }
}
