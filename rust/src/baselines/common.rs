//! Shared pieces of the baseline engines: the `OocEngine` trait, run
//! statistics, equal-width vertex chunking and raw value/edge file helpers.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::graph::{Edge, VertexId};
use crate::storage::io::{self, IoSnapshot};

/// Result of a baseline run.
#[derive(Debug, Clone)]
pub struct BaselineRun {
    pub values: Vec<f32>,
    pub iter_walls: Vec<Duration>,
    pub load_wall: Duration,
    pub total_wall: Duration,
    /// I/O delta over the iterations only (excludes prepare).
    pub io: IoSnapshot,
    /// Per-iteration I/O deltas.
    pub iter_io: Vec<IoSnapshot>,
    pub memory_bytes: u64,
    pub edges_processed: u64,
}

impl BaselineRun {
    pub fn total_iter_wall(&self) -> Duration {
        self.iter_walls.iter().sum()
    }
}

/// A baseline graph engine: builds its own on-disk layout, then iterates.
pub trait OocEngine {
    fn name(&self) -> &'static str;

    /// Build the on-disk layout from a raw edge list (the system's own
    /// preprocessing; not measured as iteration I/O).
    fn prepare(&mut self, edges: &[Edge], num_vertices: usize) -> Result<()>;

    /// Run `app` for at most `max_iters` iterations (or to convergence).
    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun>;

    /// Resident-memory estimate during `run` (Fig 11's metric).
    fn memory_estimate(&self) -> u64;
}

/// Split `n` vertices into `k` equal-width chunks; returns k+1 boundaries.
pub fn equal_chunks(n: usize, k: usize) -> Vec<VertexId> {
    let k = k.clamp(1, n.max(1));
    let mut bounds = Vec::with_capacity(k + 1);
    for i in 0..=k {
        bounds.push(((n as u64 * i as u64) / k as u64) as VertexId);
    }
    bounds.dedup();
    if bounds.len() == 1 {
        bounds.push(n as VertexId);
    }
    bounds
}

/// Which chunk a vertex falls into, given `equal_chunks` boundaries.
pub fn chunk_of(bounds: &[VertexId], v: VertexId) -> usize {
    match bounds.binary_search(&v) {
        Ok(i) => i.min(bounds.len() - 2),
        Err(i) => i - 1,
    }
}

// ---- raw little-endian files (values + edge pairs) --------------------------

/// Write an f32 value array as a raw LE file (C = 4 bytes/vertex).
pub fn write_values(path: &Path, vals: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(vals.len() * 4);
    for &v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    io::write_file(path, &buf)
}

/// Read an f32 value array.
pub fn read_values(path: &Path) -> Result<Vec<f32>> {
    values_from_bytes(&io::read_file(path)?)
}

/// Decode an f32 value array from raw LE bytes (the read-ahead path).
pub fn values_from_bytes(buf: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(buf.len() % 4 == 0, "value file not 4-aligned");
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write raw (src,dst) pairs (D = 8 bytes/edge).
pub fn write_edges(path: &Path, edges: &[Edge]) -> Result<()> {
    let mut buf = Vec::with_capacity(edges.len() * 8);
    for &(s, d) in edges {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
    }
    io::write_file(path, &buf)
}

/// Read raw (src,dst) pairs.
pub fn read_edges(path: &Path) -> Result<Vec<Edge>> {
    edges_from_bytes(&io::read_file(path)?)
}

/// Decode raw (src,dst) pairs from LE bytes (the read-ahead path).
pub fn edges_from_bytes(buf: &[u8]) -> Result<Vec<Edge>> {
    anyhow::ensure!(buf.len() % 8 == 0, "edge file not 8-aligned");
    Ok(buf
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            )
        })
        .collect())
}

/// File read-ahead depth the baseline engines stream their per-iteration
/// files with.  The baselines model single-disk systems, so a shallow
/// ordered read-ahead (overlap the *next* file with current compute) keeps
/// the comparison with GraphMP's pipelined engine fair without changing
/// any engine's byte accounting: same files, same order, same counters.
pub const READ_AHEAD_DEPTH: usize = 2;

/// Pull the next read-ahead item, which must exist (the schedule length is
/// fixed before iteration starts).
pub fn next_buf(
    stream: &mut crate::storage::prefetch::ReadAhead,
    what: &'static str,
) -> Result<Vec<u8>> {
    match stream.next() {
        Some(r) => r,
        None => anyhow::bail!("read-ahead stream exhausted early at {what}"),
    }
}

/// Fresh working directory for an engine.
pub fn fresh_dir(root: &Path) -> Result<PathBuf> {
    let _ = std::fs::remove_dir_all(root);
    std::fs::create_dir_all(root)?;
    Ok(root.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_and_are_balanced() {
        let b = equal_chunks(100, 4);
        assert_eq!(b, vec![0, 25, 50, 75, 100]);
        assert_eq!(chunk_of(&b, 0), 0);
        assert_eq!(chunk_of(&b, 24), 0);
        assert_eq!(chunk_of(&b, 25), 1);
        assert_eq!(chunk_of(&b, 99), 3);
    }

    #[test]
    fn chunks_degenerate_cases() {
        assert_eq!(equal_chunks(3, 10), vec![0, 1, 2, 3]);
        assert_eq!(equal_chunks(5, 1), vec![0, 5]);
    }

    #[test]
    fn value_and_edge_files_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_bcom_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vp = dir.join("v.bin");
        write_values(&vp, &[1.0, -2.5, f32::INFINITY]).unwrap();
        let vals = read_values(&vp).unwrap();
        assert_eq!(vals[0], 1.0);
        assert!(vals[2].is_infinite());
        let ep = dir.join("e.bin");
        write_edges(&ep, &[(1, 2), (3, 4)]).unwrap();
        assert_eq!(read_edges(&ep).unwrap(), vec![(1, 2), (3, 4)]);
    }
}
