//! Shared pieces of the baseline engines: the `OocEngine` trait, run
//! statistics, equal-width vertex chunking and raw value/edge file helpers.
//!
//! Value files are generic over the vertex-value lane (`V::BYTES` per
//! vertex); edge files optionally carry the per-edge weight lane (12 B
//! records instead of 8 B).  The classic `f32` path is the trait's default
//! type parameter, so pre-lane code reads unchanged.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use crate::apps::{VertexProgram, VertexValue};
use crate::graph::{Edge, VertexId, Weight};
use crate::storage::io::{self, IoSnapshot};

/// Result of a baseline run, typed by the program's value lane.
#[derive(Debug, Clone)]
pub struct BaselineRun<V = f32> {
    pub values: Vec<V>,
    pub iter_walls: Vec<Duration>,
    pub load_wall: Duration,
    pub total_wall: Duration,
    /// I/O delta over the iterations only (excludes prepare).
    pub io: IoSnapshot,
    /// Per-iteration I/O deltas.
    pub iter_io: Vec<IoSnapshot>,
    pub memory_bytes: u64,
    pub edges_processed: u64,
}

impl<V> BaselineRun<V> {
    pub fn total_iter_wall(&self) -> Duration {
        self.iter_walls.iter().sum()
    }
}

/// A baseline graph engine: builds its own on-disk layout, then iterates.
///
/// The trait is the object-safe `f32` facade (what `by_name` boxes); each
/// engine additionally exposes an inherent `run_typed` generic over any
/// [`VertexValue`] lane, reachable via [`super::run_typed_by_name`].
pub trait OocEngine {
    fn name(&self) -> &'static str;

    /// Build the on-disk layout from a raw edge list (the system's own
    /// preprocessing; not measured as iteration I/O).
    fn prepare(&mut self, edges: &[Edge], num_vertices: usize) -> Result<()> {
        self.prepare_weighted(edges, &[], num_vertices)
    }

    /// [`Self::prepare`] with a per-edge weight lane (parallel to `edges`;
    /// empty = unweighted).
    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()>;

    /// Run `app` for at most `max_iters` iterations (or to convergence).
    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun>;

    /// Resident-memory estimate during `run` (Fig 11's metric).
    fn memory_estimate(&self) -> u64;
}

/// Split `n` vertices into `k` equal-width chunks; returns k+1 boundaries.
pub fn equal_chunks(n: usize, k: usize) -> Vec<VertexId> {
    let k = k.clamp(1, n.max(1));
    let mut bounds = Vec::with_capacity(k + 1);
    for i in 0..=k {
        bounds.push(((n as u64 * i as u64) / k as u64) as VertexId);
    }
    bounds.dedup();
    if bounds.len() == 1 {
        bounds.push(n as VertexId);
    }
    bounds
}

/// Which chunk a vertex falls into, given `equal_chunks` boundaries.
pub fn chunk_of(bounds: &[VertexId], v: VertexId) -> usize {
    match bounds.binary_search(&v) {
        Ok(i) => i.min(bounds.len() - 2),
        Err(i) => i - 1,
    }
}

/// Bucket edges (and their parallel weight lane, empty = unweighted) into
/// `num` chunks keyed by `key(edge)` through [`chunk_of`] — the shared
/// partitioning step of the engines' prepare paths.  Input order is
/// preserved within each bucket.
pub fn bucket_weighted(
    bounds: &[VertexId],
    num: usize,
    edges: &[Edge],
    weights: &[Weight],
    key: impl Fn(Edge) -> VertexId,
) -> (Vec<Vec<Edge>>, Vec<Vec<Weight>>) {
    let weighted = !weights.is_empty();
    let mut buckets: Vec<Vec<Edge>> = vec![Vec::new(); num];
    let mut wbuckets: Vec<Vec<Weight>> = vec![Vec::new(); num];
    for (k, &e) in edges.iter().enumerate() {
        let i = chunk_of(bounds, key(e));
        buckets[i].push(e);
        if weighted {
            wbuckets[i].push(weights[k]);
        }
    }
    (buckets, wbuckets)
}

// ---- raw little-endian files (values + edge records) ------------------------

/// Write a value array as a raw LE file (C = `V::BYTES` bytes/vertex).
pub fn write_values<V: VertexValue>(path: &Path, vals: &[V]) -> Result<()> {
    let mut buf = Vec::with_capacity(vals.len() * V::BYTES);
    for &v in vals {
        v.write_le(&mut buf);
    }
    io::write_file(path, &buf)
}

/// Read a value array.
pub fn read_values<V: VertexValue>(path: &Path) -> Result<Vec<V>> {
    values_from_bytes(&io::read_file(path)?)
}

/// Decode a value array from raw LE bytes (the read-ahead path).
pub fn values_from_bytes<V: VertexValue>(buf: &[u8]) -> Result<Vec<V>> {
    let mut out = Vec::new();
    values_from_bytes_into(buf, &mut out)?;
    Ok(out)
}

/// [`values_from_bytes`] into a caller-owned buffer (cleared first) — the
/// baselines' shared fetch path re-reads value files every iteration, and
/// decoding into a reused buffer keeps their steady state allocation-free
/// too (the same discipline as the VSW engine's scratch arenas).
pub fn values_from_bytes_into<V: VertexValue>(buf: &[u8], out: &mut Vec<V>) -> Result<()> {
    anyhow::ensure!(buf.len() % V::BYTES == 0, "value file not {}-aligned", V::BYTES);
    out.clear();
    out.reserve(buf.len() / V::BYTES);
    out.extend(buf.chunks_exact(V::BYTES).map(V::read_le));
    Ok(())
}

/// Write raw edge records: `(src,dst)` pairs (D = 8 B/edge), or
/// `(src,dst,weight)` triples (12 B/edge) when `weights` is non-empty.
pub fn write_edges_w(path: &Path, edges: &[Edge], weights: &[Weight]) -> Result<()> {
    let weighted = !weights.is_empty();
    if weighted {
        anyhow::ensure!(weights.len() == edges.len(), "weights must be parallel to edges");
    }
    let rec = if weighted { 12 } else { 8 };
    let mut buf = Vec::with_capacity(edges.len() * rec);
    for (k, &(s, d)) in edges.iter().enumerate() {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        if weighted {
            buf.extend_from_slice(&weights[k].to_le_bytes());
        }
    }
    io::write_file(path, &buf)
}

/// Write raw unweighted `(src,dst)` pairs (D = 8 bytes/edge).
pub fn write_edges(path: &Path, edges: &[Edge]) -> Result<()> {
    write_edges_w(path, edges, &[])
}

/// Read raw unweighted `(src,dst)` pairs.
pub fn read_edges(path: &Path) -> Result<Vec<Edge>> {
    edges_from_bytes(&io::read_file(path)?)
}

/// Decode raw unweighted `(src,dst)` pairs from LE bytes.
pub fn edges_from_bytes(buf: &[u8]) -> Result<Vec<Edge>> {
    let (edges, _) = edges_from_bytes_w(buf, false)?;
    Ok(edges)
}

/// Decode raw edge records from LE bytes; the caller says whether the file
/// was written with the weight lane (`weighted` ⇒ 12 B records).
pub fn edges_from_bytes_w(buf: &[u8], weighted: bool) -> Result<(Vec<Edge>, Vec<Weight>)> {
    let rec = if weighted { 12 } else { 8 };
    anyhow::ensure!(buf.len() % rec == 0, "edge file not {rec}-aligned");
    let n = buf.len() / rec;
    let mut edges = Vec::with_capacity(n);
    let mut weights = Vec::with_capacity(if weighted { n } else { 0 });
    for c in buf.chunks_exact(rec) {
        edges.push((
            u32::from_le_bytes(c[0..4].try_into().unwrap()),
            u32::from_le_bytes(c[4..8].try_into().unwrap()),
        ));
        if weighted {
            weights.push(f32::from_le_bytes(c[8..12].try_into().unwrap()));
        }
    }
    Ok((edges, weights))
}

/// Hottest-first chunk schedule for the baselines' ordered read-ahead —
/// the governor's priority-schedule idea (`engine::Governor::schedule`)
/// extended to the PSW/ESG/DSW/VSP comparisons so adaptive-GraphMP
/// ablations race engines that also order their I/O by activity.
///
/// Heat is the number of a chunk's vertices that changed in the *previous*
/// iteration (the baselines have no Bloom filters; observed activity is
/// their equivalent signal).  The order is deterministic — heat
/// descending, chunk id ascending, decided only from completed iterations
/// — and every reordered loop writes only its own chunk's vertex range
/// while reading the previous iteration's values, so results are identical
/// in any order, bit for bit.  Disabled, [`Self::order`] returns file
/// order: the original schedules unchanged.
pub struct HeatSchedule {
    enabled: bool,
    /// Heat driving this iteration's order (last iteration's counts).
    cur: Vec<u64>,
    /// Counts accumulating during the current iteration.
    next: Vec<u64>,
}

impl HeatSchedule {
    pub fn new(chunks: usize, enabled: bool) -> Self {
        Self { enabled, cur: vec![0; chunks], next: vec![0; chunks] }
    }

    /// This iteration's chunk issue order (a permutation of `0..chunks`).
    pub fn order(&self) -> Vec<usize> {
        let mut o: Vec<usize> = (0..self.cur.len()).collect();
        if self.enabled {
            o.sort_by_key(|&i| (std::cmp::Reverse(self.cur[i]), i));
        }
        o
    }

    /// Record how many of `chunk`'s vertices changed this iteration.
    pub fn record(&mut self, chunk: usize, changed: u64) {
        self.next[chunk] += changed;
    }

    /// End of iteration: recorded counts drive the next order.
    pub fn advance(&mut self) {
        std::mem::swap(&mut self.cur, &mut self.next);
        self.next.fill(0);
    }
}

/// File read-ahead depth the baseline engines stream their per-iteration
/// files with.  The baselines model single-disk systems, so a shallow
/// ordered read-ahead (overlap the *next* file with current compute) keeps
/// the comparison with GraphMP's pipelined engine fair without changing
/// any engine's byte accounting: same files, same order, same counters.
pub const READ_AHEAD_DEPTH: usize = 2;

/// Pull the next read-ahead item, which must exist (the schedule length is
/// fixed before iteration starts).
pub fn next_buf(
    stream: &mut crate::storage::prefetch::ReadAhead,
    what: &'static str,
) -> Result<Vec<u8>> {
    match stream.next() {
        Some(r) => r,
        None => anyhow::bail!("read-ahead stream exhausted early at {what}"),
    }
}

/// Fresh working directory for an engine.
pub fn fresh_dir(root: &Path) -> Result<PathBuf> {
    let _ = std::fs::remove_dir_all(root);
    std::fs::create_dir_all(root)?;
    Ok(root.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_and_are_balanced() {
        let b = equal_chunks(100, 4);
        assert_eq!(b, vec![0, 25, 50, 75, 100]);
        assert_eq!(chunk_of(&b, 0), 0);
        assert_eq!(chunk_of(&b, 24), 0);
        assert_eq!(chunk_of(&b, 25), 1);
        assert_eq!(chunk_of(&b, 99), 3);
    }

    #[test]
    fn heat_schedule_orders_hottest_first_deterministically() {
        let mut s = HeatSchedule::new(4, true);
        assert_eq!(s.order(), vec![0, 1, 2, 3], "no history = file order");
        s.record(2, 10);
        s.record(0, 3);
        s.record(3, 10);
        assert_eq!(s.order(), vec![0, 1, 2, 3], "counts apply only after advance");
        s.advance();
        // heat desc, id asc on ties
        assert_eq!(s.order(), vec![2, 3, 0, 1]);
        assert_eq!(s.order(), vec![2, 3, 0, 1], "same inputs, same order");
        s.advance();
        assert_eq!(s.order(), vec![0, 1, 2, 3], "heat resets each iteration");
        // disabled: always file order
        let mut s = HeatSchedule::new(3, false);
        s.record(2, 99);
        s.advance();
        assert_eq!(s.order(), vec![0, 1, 2]);
    }

    #[test]
    fn chunks_degenerate_cases() {
        assert_eq!(equal_chunks(3, 10), vec![0, 1, 2, 3]);
        assert_eq!(equal_chunks(5, 1), vec![0, 5]);
    }

    #[test]
    fn value_and_edge_files_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_bcom_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vp = dir.join("v.bin");
        write_values(&vp, &[1.0f32, -2.5, f32::INFINITY]).unwrap();
        let vals: Vec<f32> = read_values(&vp).unwrap();
        assert_eq!(vals[0], 1.0);
        assert!(vals[2].is_infinite());
        let ep = dir.join("e.bin");
        write_edges(&ep, &[(1, 2), (3, 4)]).unwrap();
        assert_eq!(read_edges(&ep).unwrap(), vec![(1, 2), (3, 4)]);
    }

    #[test]
    fn typed_value_files_roundtrip_all_lanes() {
        let dir = std::env::temp_dir().join(format!("gmp_bcomt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tv.bin");
        write_values(&p, &[1u64, u64::MAX, 7]).unwrap();
        assert_eq!(read_values::<u64>(&p).unwrap(), vec![1, u64::MAX, 7]);
        write_values(&p, &[3u32, 9]).unwrap();
        assert_eq!(read_values::<u32>(&p).unwrap(), vec![3, 9]);
        write_values(&p, &[0.5f64, -1.25]).unwrap();
        assert_eq!(read_values::<f64>(&p).unwrap(), vec![0.5, -1.25]);
        // a u64 file is not 4-aligned-compatible garbage for u32 semantics,
        // but alignment itself is checked
        write_values(&p, &[1u32, 2, 3]).unwrap();
        assert!(values_from_bytes::<u64>(&std::fs::read(&p).unwrap()).is_err());
    }

    #[test]
    fn weighted_edge_files_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gmp_bcomw_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("we.bin");
        let edges = vec![(1, 2), (3, 4), (5, 6)];
        let weights = vec![0.25f32, 1.0, 2.0];
        write_edges_w(&p, &edges, &weights).unwrap();
        let (e, w) = edges_from_bytes_w(&std::fs::read(&p).unwrap(), true).unwrap();
        assert_eq!(e, edges);
        assert_eq!(w, weights);
    }
}
