//! Baseline engines: working reimplementations of the computation models
//! GraphMP is evaluated against (paper §III, Table II).
//!
//! | model | system    | module    | read/iter              | write/iter        |
//! |-------|-----------|-----------|------------------------|-------------------|
//! | PSW   | GraphChi  | [`psw`]   | C·V + 2(C+D)·E         | C·V + 2(C+D)·E    |
//! | ESG   | X-Stream  | [`esg`]   | C·V + (C+D)·E          | C·V + C·E         |
//! | VSP   | VENUS     | [`vsp`]   | C(1+δ)·V + D·E         | C·V               |
//! | DSW   | GridGraph | [`dsw`]   | C·√P·V + D·E           | C·√P·V            |
//! | —     | GraphMat  | [`inmem`] | load once              | —                 |
//!
//! Each engine builds its own on-disk layout from a raw edge list (with the
//! optional per-edge weight lane), then iterates doing **real file I/O**
//! for the dominant streams; fine-grained positioned accesses that a real
//! system would serve from sliding windows are accounted through
//! `storage::io::account_virtual_*` so the measured byte counters still
//! match the model columns above (validated by `benches/table2_iomodel.rs`).
//! All engines converge to the same fixpoints as the VSW engine on every
//! value lane (see `tests/baseline_convergence.rs` and the conformance
//! matrix in `tests/engine_equivalence.rs`).

pub mod common;
pub mod dsw;
pub mod esg;
pub mod inmem;
pub mod psw;
pub mod vsp;

pub use common::{BaselineRun, OocEngine};
pub use dsw::DswEngine;
pub use esg::EsgEngine;
pub use inmem::InMemEngine;
pub use psw::PswEngine;
pub use vsp::VspEngine;

use crate::apps::{VertexProgram, VertexValue};
use crate::graph::{Edge, Weight};

/// Resolve a CLI name/alias to its canonical engine token — the single
/// alias table both [`by_name`] and [`run_typed_by_name`] dispatch on, so
/// the two paths (and their error message) can never drift.
fn canonical(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "psw" | "graphchi" => "psw",
        "esg" | "x-stream" | "xstream" => "esg",
        "dsw" | "gridgraph" => "dsw",
        "vsp" | "venus" => "vsp",
        "inmem" | "graphmat" => "inmem",
        other => anyhow::bail!("unknown baseline {other:?} (psw|esg|dsw|vsp|inmem)"),
    })
}

/// Construct a baseline by CLI name, rooted at `dir` (the `f32` trait-object
/// facade; typed lanes go through [`run_typed_by_name`]).
pub fn by_name(name: &str, dir: std::path::PathBuf) -> anyhow::Result<Box<dyn OocEngine>> {
    Ok(match canonical(name)? {
        "psw" => Box::new(PswEngine::new(dir)),
        "esg" => Box::new(EsgEngine::new(dir)),
        "dsw" => Box::new(DswEngine::new(dir)),
        "vsp" => Box::new(VspEngine::new(dir)),
        "inmem" => Box::new(InMemEngine::new()),
        _ => unreachable!("canonical() returns only known tokens"),
    })
}

/// Canonical display name for a baseline CLI token — derived from the
/// engine's own `OocEngine::name` (single source; engine construction
/// touches no disk), so figures and the CLI can never drift from it.
pub fn display_name(name: &str) -> anyhow::Result<&'static str> {
    Ok(by_name(name, std::env::temp_dir())?.name())
}

/// Prepare + run a baseline by name on any value lane: the typed
/// counterpart of [`by_name`] + `prepare`/`run`, used by the CLI and the
/// cross-engine conformance matrix.  `weights` empty ⇒ unweighted.
pub fn run_typed_by_name<V: VertexValue>(
    name: &str,
    dir: std::path::PathBuf,
    edges: &[Edge],
    weights: &[Weight],
    num_vertices: usize,
    app: &dyn VertexProgram<V>,
    max_iters: usize,
) -> anyhow::Result<BaselineRun<V>> {
    match canonical(name)? {
        "psw" => {
            let mut e = PswEngine::new(dir);
            e.prepare_weighted(edges, weights, num_vertices)?;
            e.run_typed(app, max_iters)
        }
        "esg" => {
            let mut e = EsgEngine::new(dir);
            e.prepare_weighted(edges, weights, num_vertices)?;
            e.run_typed(app, max_iters)
        }
        "dsw" => {
            let mut e = DswEngine::new(dir);
            e.prepare_weighted(edges, weights, num_vertices)?;
            e.run_typed(app, max_iters)
        }
        "vsp" => {
            let mut e = VspEngine::new(dir);
            e.prepare_weighted(edges, weights, num_vertices)?;
            e.run_typed(app, max_iters)
        }
        "inmem" => {
            let mut e = InMemEngine::new();
            e.prepare_weighted(edges, weights, num_vertices)?;
            e.run_typed(app, max_iters)
        }
        _ => unreachable!("canonical() returns only known tokens"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn by_name_resolves_all() {
        let dir = std::env::temp_dir();
        for n in ["psw", "graphchi", "esg", "dsw", "vsp", "inmem", "graphmat"] {
            assert!(super::by_name(n, dir.clone()).is_ok(), "{n}");
        }
        assert!(super::by_name("zzz", dir).is_err());
    }

    #[test]
    fn typed_dispatch_runs_every_engine() {
        use crate::apps::{LabelProp, VertexProgram};
        let app: &dyn VertexProgram<u64> = &LabelProp;
        let edges = vec![(0u32, 1u32), (1, 2), (2, 0)];
        for n in ["psw", "esg", "dsw", "vsp", "inmem"] {
            let dir = std::env::temp_dir().join(format!(
                "gmp_basetyped_{n}_{}",
                std::process::id()
            ));
            let run = super::run_typed_by_name(n, dir, &edges, &[], 3, app, 50).unwrap();
            assert_eq!(run.values, vec![0, 0, 0], "{n}");
        }
        assert!(
            super::run_typed_by_name("zzz", std::env::temp_dir(), &edges, &[], 3, app, 1)
                .is_err()
        );
    }
}
