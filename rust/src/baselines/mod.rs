//! Baseline engines: working reimplementations of the computation models
//! GraphMP is evaluated against (paper §III, Table II).
//!
//! | model | system    | module    | read/iter              | write/iter        |
//! |-------|-----------|-----------|------------------------|-------------------|
//! | PSW   | GraphChi  | [`psw`]   | C·V + 2(C+D)·E         | C·V + 2(C+D)·E    |
//! | ESG   | X-Stream  | [`esg`]   | C·V + (C+D)·E          | C·V + C·E         |
//! | VSP   | VENUS     | [`vsp`]   | C(1+δ)·V + D·E         | C·V               |
//! | DSW   | GridGraph | [`dsw`]   | C·√P·V + D·E           | C·√P·V            |
//! | —     | GraphMat  | [`inmem`] | load once              | —                 |
//!
//! Each engine builds its own on-disk layout from a raw edge list, then
//! iterates doing **real file I/O** for the dominant streams; fine-grained
//! positioned accesses that a real system would serve from sliding windows
//! are accounted through `storage::io::account_virtual_*` so the measured
//! byte counters still match the model columns above (validated by
//! `benches/table2_iomodel.rs`).  All engines converge to the same fixpoints
//! as the VSW engine (see `tests/baseline_convergence.rs`).

pub mod common;
pub mod dsw;
pub mod esg;
pub mod inmem;
pub mod psw;
pub mod vsp;

pub use common::{BaselineRun, OocEngine};
pub use dsw::DswEngine;
pub use esg::EsgEngine;
pub use inmem::InMemEngine;
pub use psw::PswEngine;
pub use vsp::VspEngine;

/// Construct a baseline by CLI name, rooted at `dir`.
pub fn by_name(name: &str, dir: std::path::PathBuf) -> anyhow::Result<Box<dyn OocEngine>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "psw" | "graphchi" => Box::new(PswEngine::new(dir)),
        "esg" | "x-stream" | "xstream" => Box::new(EsgEngine::new(dir)),
        "dsw" | "gridgraph" => Box::new(DswEngine::new(dir)),
        "vsp" | "venus" => Box::new(VspEngine::new(dir)),
        "inmem" | "graphmat" => Box::new(InMemEngine::new()),
        other => anyhow::bail!("unknown baseline {other:?} (psw|esg|dsw|vsp|inmem)"),
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn by_name_resolves_all() {
        let dir = std::env::temp_dir();
        for n in ["psw", "graphchi", "esg", "dsw", "vsp", "inmem", "graphmat"] {
            assert!(super::by_name(n, dir.clone()).is_ok(), "{n}");
        }
        assert!(super::by_name("zzz", dir).is_err());
    }
}
