//! DSW — the dual sliding windows model of **GridGraph** (Zhu et al., ATC
//! '15), as analyzed in paper §III-D.
//!
//! Vertices are split into √P equal chunks; edges into a √P×√P grid of
//! blocks (row = source chunk, column = destination chunk).  One iteration
//! processes the grid column by column:
//!
//! ```text
//! for j in 0..√P:                 # destination window
//!     acc = identity; old = read chunk_j          (C·V/√P)
//!     for i in 0..√P:             # source window slides
//!         src = read chunk_i                      (C·V/√P each → C·√P·V total)
//!         stream block_(i,j)                      (D·E total)
//!         acc[dst] = combine(acc[dst], gather(src[u]))
//!     write chunk_j = apply(acc, old)             (C·V/√P → C·V... ×√P = C·√P·V)
//! ```
//!
//! GridGraph's selective scheduling (observed by the paper in Fig 9) is
//! reproduced: a source chunk with no active vertex lets the whole block
//! row be skipped without reading it.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::baselines::common::{self, BaselineRun, OocEngine};
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::storage::io;
use crate::storage::prefetch::ReadAhead;
use crate::util::bitset::BitSet;

/// Grid dimension √P (GridGraph's P is the block count).
const GRID: usize = 4;

pub struct DswEngine {
    dir: PathBuf,
    bounds: Vec<VertexId>,
    num_vertices: usize,
    num_edges: u64,
    out_deg: Vec<u32>,
    weighted: bool,
    /// Enable source-chunk selective scheduling.
    pub selective: bool,
    adaptive_order: bool,
}

impl DswEngine {
    pub fn new(dir: PathBuf) -> Self {
        Self {
            dir,
            bounds: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            out_deg: Vec::new(),
            weighted: false,
            selective: true,
            adaptive_order: false,
        }
    }

    /// Process destination columns hottest-first (previous iteration's
    /// changed counts) instead of in grid order.  Column order never
    /// changes results: each column folds its block rows in the same
    /// `0..q` order and writes only its own double-buffered chunk.
    pub fn set_adaptive_order(&mut self, on: bool) {
        self.adaptive_order = on;
    }

    fn block_path(&self, i: usize, j: usize) -> PathBuf {
        self.dir.join(format!("dsw_block_{i:02}_{j:02}.bin"))
    }

    fn chunk_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("dsw_chunk_{i:02}.bin"))
    }

    fn chunk_next_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("dsw_chunk_next_{i:02}.bin"))
    }

    fn q(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Memory model with an explicit lane width `c`: two vertex chunks —
    /// 2·C·V/√P.
    fn memory_estimate_lane(&self, c: u64) -> u64 {
        2 * c * self.num_vertices as u64 / self.q().max(1) as u64
    }

    /// Typed run over any value lane (see trait docs).
    pub fn run_typed<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let n = self.num_vertices;
        let q = self.q();
        let ctx = ProgramContext { num_vertices: n as u64 };
        let t0 = Instant::now();

        let init: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        for i in 0..q {
            let (lo, hi) = (self.bounds[i] as usize, self.bounds[i + 1] as usize);
            common::write_values(&self.chunk_path(i), &init[lo..hi])?;
        }
        let load_wall = t0.elapsed();

        // Row skipping is only sound for monotone (Min/Max) programs — a
        // quiet source chunk re-offers the same already-applied folds.
        // Sum programs recompute the full in-edge sum each iteration, so a
        // skipped row would corrupt it.
        let selective = self.selective && app.reduce().is_monotone();

        // chunk-level activity: initially per the app's initially_active
        let mut chunk_active = BitSet::new(q);
        for v in 0..n as VertexId {
            if app.initially_active(v, &ctx) {
                chunk_active.set(common::chunk_of(&self.bounds, v));
            }
        }

        let io_start = io::snapshot();
        let mut iter_walls = Vec::new();
        let mut iter_io = Vec::new();
        let mut edges_processed = 0u64;
        let mut sched = common::HeatSchedule::new(q, self.adaptive_order);
        // reusable value-decode buffers (the shared fetch path's scratch):
        // every (column, block) pair re-reads value files each iteration,
        // so decoding into fresh vectors dominated steady-state allocation
        let mut old_buf: Vec<V> = Vec::new();
        let mut src_buf: Vec<V> = Vec::new();
        let mut chunk_buf: Vec<V> = Vec::new();

        for _iter in 0..max_iters {
            let t_iter = Instant::now();
            let io_before = io::snapshot();
            let mut changed = false;
            let mut next_active = BitSet::new(q);

            // the whole iteration's read schedule is determined up front by
            // `chunk_active` (chunk files only change at the end-of-iteration
            // rename), so one ordered read-ahead covers every column — the
            // skipped rows are never read, keeping Table II's byte counts
            // column order: hottest destination first under adaptive
            // order, grid order otherwise (the inner block-row order is
            // fixed, so the per-column fold is identical either way)
            let order = sched.order();
            let mut schedule = Vec::new();
            for &j in &order {
                schedule.push(self.chunk_path(j));
                for i in 0..q {
                    if selective && !chunk_active.get(i) {
                        continue;
                    }
                    schedule.push(self.chunk_path(i));
                    schedule.push(self.block_path(i, j));
                }
            }
            let mut stream = ReadAhead::new(schedule, common::READ_AHEAD_DEPTH);

            for &j in &order {
                let (lo_j, hi_j) = (self.bounds[j], self.bounds[j + 1]);
                common::values_from_bytes_into(
                    &common::next_buf(&mut stream, "dsw column")?,
                    &mut old_buf,
                )?;
                let old = &old_buf;
                let reduce = app.reduce();
                let mut acc = vec![reduce.identity::<V>(); (hi_j - lo_j) as usize];
                // GridGraph still *applies* for inactive columns (values may
                // decay to apply(identity, old)), so we always run apply.
                for i in 0..q {
                    if selective && !chunk_active.get(i) {
                        continue; // skip row: no active sources in chunk i
                    }
                    let lo_i = self.bounds[i];
                    // C·V/√P
                    common::values_from_bytes_into(
                        &common::next_buf(&mut stream, "dsw chunk")?,
                        &mut src_buf,
                    )?;
                    let src = &src_buf;
                    // D·E
                    let (block, bweights) = common::edges_from_bytes_w(
                        &common::next_buf(&mut stream, "dsw block")?,
                        self.weighted,
                    )?;
                    for (kk, (s, d)) in block.into_iter().enumerate() {
                        let w = if self.weighted { bweights[kk] } else { 1.0 };
                        let k = (d - lo_j) as usize;
                        acc[k] = reduce.combine(
                            acc[k],
                            app.gather(src[(s - lo_i) as usize], self.out_deg[s as usize], w),
                        );
                        edges_processed += 1;
                    }
                }
                chunk_buf.clear();
                chunk_buf.extend_from_slice(old);
                let mut col_changed = 0u64;
                for k in 0..acc.len() {
                    // PageRank-style Sum programs recompute from the full
                    // in-edge set; with skipped rows the sum would be partial,
                    // so Sum programs disable row skipping (see above).
                    let nv = app.apply(acc[k], old[k], &ctx);
                    if V::changed(old[k], nv, 0.0) {
                        changed = true;
                        col_changed += 1;
                        next_active.set(j);
                    }
                    chunk_buf[k] = nv;
                }
                sched.record(j, col_changed);
                // double-buffered chunk write (Jacobi semantics): later
                // columns must still read this iteration's *input* values
                common::write_values(&self.chunk_next_path(j), &chunk_buf)?; // C·V/√P
            }
            for j in 0..q {
                std::fs::rename(self.chunk_next_path(j), self.chunk_path(j))?;
            }

            chunk_active = next_active;
            sched.advance();
            iter_walls.push(t_iter.elapsed());
            iter_io.push(io::snapshot().since(&io_before));
            if !changed {
                break;
            }
        }

        let mut values = Vec::with_capacity(n);
        for i in 0..q {
            values.extend(common::read_values::<V>(&self.chunk_path(i))?);
        }
        Ok(BaselineRun {
            values,
            iter_walls,
            load_wall,
            total_wall: t0.elapsed(),
            io: io::snapshot().since(&io_start),
            iter_io,
            memory_bytes: self.memory_estimate_lane(V::BYTES as u64),
            edges_processed,
        })
    }

    /// Run with row skipping disabled — required for Sum-monoid programs
    /// (PageRank) whose apply needs the *complete* in-edge sum.
    pub fn run_full<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let was = self.selective;
        self.selective = false;
        let r = self.run_typed(app, max_iters);
        self.selective = was;
        r
    }
}

impl OocEngine for DswEngine {
    fn name(&self) -> &'static str {
        "dsw(gridgraph)"
    }

    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()> {
        common::fresh_dir(&self.dir)?;
        let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
        self.out_deg = degrees.out_deg;
        self.bounds = common::equal_chunks(num_vertices, GRID);
        self.num_vertices = num_vertices;
        self.num_edges = edges.len() as u64;
        self.weighted = !weights.is_empty();
        let q = self.q();
        let mut blocks: Vec<Vec<Edge>> = vec![Vec::new(); q * q];
        let mut wblocks: Vec<Vec<Weight>> = vec![Vec::new(); q * q];
        for (k, &(s, d)) in edges.iter().enumerate() {
            let i = common::chunk_of(&self.bounds, s);
            let j = common::chunk_of(&self.bounds, d);
            blocks[i * q + j].push((s, d));
            if self.weighted {
                wblocks[i * q + j].push(weights[k]);
            }
        }
        for i in 0..q {
            for j in 0..q {
                common::write_edges_w(
                    &self.block_path(i, j),
                    &blocks[i * q + j],
                    &wblocks[i * q + j],
                )?;
            }
        }
        Ok(())
    }

    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun> {
        self.run_typed(app, max_iters)
    }

    /// GridGraph keeps two vertex chunks in memory: 2·C·V/√P (f32 C=4).
    fn memory_estimate(&self) -> u64 {
        self.memory_estimate_lane(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, Sssp, WeightedSssp};
    use crate::graph::generator;

    fn reference(
        app: &dyn VertexProgram,
        edges: &[(u32, u32)],
        n: usize,
        iters: usize,
    ) -> Vec<f32> {
        let ctx = ProgramContext { num_vertices: n as u64 };
        let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut out_deg = vec![0u32; n];
        for &(s, d) in edges {
            in_adj[d as usize].push(s);
            out_deg[s as usize] += 1;
        }
        let mut vals: Vec<f32> = (0..n).map(|v| app.init(v as u32, &ctx)).collect();
        for _ in 0..iters {
            let next: Vec<f32> = (0..n)
                .map(|v| app.update(v as u32, &in_adj[v], &vals, &out_deg, &ctx))
                .collect();
            let same = next
                .iter()
                .zip(&vals)
                .all(|(a, b)| (a.is_infinite() && b.is_infinite()) || a == b);
            vals = next;
            if same {
                break;
            }
        }
        vals
    }

    #[test]
    fn dsw_pagerank_full_matches_reference() {
        let edges = generator::erdos_renyi(150, 900, 21);
        let mut eng = DswEngine::new(
            std::env::temp_dir().join(format!("gmp_dsw_t_{}", std::process::id())),
        );
        eng.prepare(&edges, 150).unwrap();
        let run = eng.run_full(&PageRank::default(), 4).unwrap();
        let want = reference(&PageRank::default(), &edges, 150, 4);
        for (i, (a, b)) in run.values.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "v{i}: {a} vs {b}");
        }
    }

    #[test]
    fn dsw_sssp_selective_matches_reference_and_skips() {
        let edges = generator::erdos_renyi(160, 700, 8);
        let mut eng = DswEngine::new(
            std::env::temp_dir().join(format!("gmp_dsw_s_{}", std::process::id())),
        );
        eng.prepare(&edges, 160).unwrap();
        let run = eng.run(&Sssp { source: 0 }, 100).unwrap();
        let want = reference(&Sssp { source: 0 }, &edges, 160, 200);
        for (i, (a, b)) in run.values.iter().zip(&want).enumerate() {
            assert!(
                (a.is_infinite() && b.is_infinite()) || a == b,
                "v{i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn dsw_weighted_sssp_through_grid_blocks() {
        // weights must survive the grid bucketing: 0 -(2)-> 1 -(0.25)-> 2,
        // plus a direct heavy edge 0 -(9)-> 2
        let edges = vec![(0u32, 1u32), (1, 2), (0, 2)];
        let weights = vec![2.0f32, 0.25, 9.0];
        let mut eng = DswEngine::new(
            std::env::temp_dir().join(format!("gmp_dsw_w_{}", std::process::id())),
        );
        eng.prepare_weighted(&edges, &weights, 3).unwrap();
        let run = eng.run_typed(&WeightedSssp { source: 0 }, 50).unwrap();
        assert_eq!(run.values, vec![0.0, 2.0, 2.25]);
    }
}
