//! In-memory baseline — the **GraphMat** role (Sundaram et al., VLDB'15) in
//! the paper's Fig 6/7 comparison.
//!
//! GraphMat maps vertex programs to SpMV over an in-memory sparse matrix.
//! Faithful aspects reproduced here:
//!
//! * a heavyweight **load phase** that materializes both edge directions
//!   (in-CSR for the pull computation + out-CSR as GraphMat's CSC twin) —
//!   this is why GraphMat needed 122 GB and 390 s loading Twitter while
//!   GraphMP needed 7.3 GB and 30 s (Fig 6);
//! * fast iterations (no disk I/O at all once loaded);
//! * SpMV-style per-iteration full sweeps.
//!
//! `run_typed` is the cross-engine conformance matrix's **oracle**: a
//! single-threaded synchronous sweep over any value lane.

use std::time::Instant;

use anyhow::Result;

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::baselines::common::{BaselineRun, OocEngine};
use crate::graph::csr::{Csr, OutCsr};
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::storage::io;

#[derive(Default)]
pub struct InMemEngine {
    in_csr: Option<Csr>,
    out_csr: Option<OutCsr>,
    out_deg: Vec<u32>,
    num_vertices: usize,
    num_edges: u64,
}

impl InMemEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// The faithful load phase: GraphMat ingests a *text* edge list (the
    /// paper's 25 GB CSV for Twitter) — read through the accounted/throttled
    /// I/O layer, integer-parsed line by line, then both CSR directions are
    /// built.  This is what Fig 6 times; `prepare` (from an in-memory vec)
    /// remains for benches where load cost is not the subject.
    pub fn prepare_from_text(&mut self, path: &std::path::Path, num_vertices: usize) -> Result<()> {
        let bytes = io::read_file(path)?;
        let text = std::str::from_utf8(&bytes)?;
        let mut edges: Vec<Edge> = Vec::new();
        for line in text.lines() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let mut it = t.split_whitespace();
            let (Some(a), Some(b)) = (it.next(), it.next()) else {
                anyhow::bail!("bad edge line: {t:?}");
            };
            edges.push((a.parse()?, b.parse()?));
        }
        self.build(&edges, &[], num_vertices);
        Ok(())
    }

    /// Memory model with an explicit lane width `c`: both CSR directions
    /// (u32 columns regardless of lane) + degrees + two value arrays.
    fn memory_estimate_lane(&self, c: u64) -> u64 {
        let v = self.num_vertices as u64;
        let e = self.num_edges;
        4 * e + 4 * v          // in-CSR
            + 4 * e + 8 * v    // out-CSR
            + 8 * v            // degrees
            + 2 * c * v        // src+dst values
    }

    fn build(&mut self, edges: &[Edge], weights: &[Weight], num_vertices: usize) {
        let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
        self.out_deg = degrees.out_deg;
        self.in_csr = Some(Csr::from_edges_weighted(
            0,
            num_vertices as VertexId,
            edges,
            weights,
        ));
        self.out_csr = Some(OutCsr::from_edges(num_vertices, edges));
        self.num_vertices = num_vertices;
        self.num_edges = edges.len() as u64;
    }

    /// Typed run over any value lane — the single-threaded synchronous
    /// reference sweep (Algorithm 2 applied to every vertex each
    /// iteration).
    pub fn run_typed<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let n = self.num_vertices;
        let csr = self.in_csr.as_ref().expect("prepare first");
        let ctx = ProgramContext { num_vertices: n as u64 };
        let t0 = Instant::now();
        let io_start = io::snapshot();

        let mut vals: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        let mut next = vals.clone();
        let mut iter_walls = Vec::new();
        let mut iter_io = Vec::new();
        let mut edges_processed = 0u64;
        let reduce = app.reduce();

        for _iter in 0..max_iters {
            let t_iter = Instant::now();
            let io_before = io::snapshot();
            let mut changed = false;
            for v in 0..n {
                let s = csr.row_ptr[v] as usize;
                let e = csr.row_ptr[v + 1] as usize;
                let mut acc = reduce.identity();
                for k in s..e {
                    let u = csr.col[k] as usize;
                    acc = reduce.combine(
                        acc,
                        app.gather(vals[u], self.out_deg[u], csr.weight(k)),
                    );
                }
                let old = vals[v];
                let nv = app.apply(acc, old, &ctx);
                if V::changed(old, nv, 0.0) {
                    changed = true;
                }
                next[v] = nv;
            }
            edges_processed += self.num_edges;
            std::mem::swap(&mut vals, &mut next);
            iter_walls.push(t_iter.elapsed());
            iter_io.push(io::snapshot().since(&io_before));
            if !changed {
                break;
            }
        }

        Ok(BaselineRun {
            values: vals,
            iter_walls,
            load_wall: std::time::Duration::ZERO, // loading happened in prepare
            total_wall: t0.elapsed(),
            io: io::snapshot().since(&io_start),
            iter_io,
            memory_bytes: self.memory_estimate_lane(V::BYTES as u64),
            edges_processed,
        })
    }
}

impl OocEngine for InMemEngine {
    fn name(&self) -> &'static str {
        "inmem(graphmat)"
    }

    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()> {
        // the load phase GraphMat pays on every application start: build
        // both directions + degree arrays
        self.build(edges, weights, num_vertices);
        // account the edge-list ingestion as read I/O (GraphMat reads the
        // raw graph file once; weighted records are 12 B)
        let rec = if weights.is_empty() { 8 } else { 12 };
        io::account_virtual_read(rec * edges.len() as u64);
        Ok(())
    }

    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun> {
        self.run_typed(app, max_iters)
    }

    /// The whole graph in memory, both directions, plus working arrays:
    /// GraphMat's defining cost (f32 lane, C=4).
    fn memory_estimate(&self) -> u64 {
        self.memory_estimate_lane(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{LabelProp, PageRank, WeightedSssp};
    use crate::graph::generator;

    #[test]
    fn inmem_pagerank_is_probability_distribution() {
        // strongly-connected ring + chords so PR sums to 1
        let n = 64u32;
        let mut edges: Vec<Edge> = (0..n).map(|v| (v, (v + 1) % n)).collect();
        edges.extend((0..n).map(|v| (v, (v + 7) % n)));
        let mut eng = InMemEngine::new();
        eng.prepare(&edges, n as usize).unwrap();
        let run = eng.run(&PageRank::default(), 60).unwrap();
        let sum: f32 = run.values.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        // no disk I/O during iterations
        assert_eq!(run.io.bytes_read, 0);
        assert_eq!(run.io.bytes_written, 0);
    }

    #[test]
    fn memory_far_exceeds_sem_engines() {
        let edges = generator::erdos_renyi(1000, 20_000, 5);
        let mut eng = InMemEngine::new();
        eng.prepare(&edges, 1000).unwrap();
        // ≥ both edge directions
        assert!(eng.memory_estimate() > 2 * 4 * 20_000);
    }

    #[test]
    fn typed_and_weighted_runs_work() {
        // a path with non-unit weights: 0 -(0.5)-> 1 -(0.25)-> 2
        let edges = vec![(0, 1), (1, 2)];
        let weights = vec![0.5f32, 0.25];
        let mut eng = InMemEngine::new();
        eng.prepare_weighted(&edges, &weights, 3).unwrap();
        let run = eng.run_typed(&WeightedSssp { source: 0 }, 100).unwrap();
        assert_eq!(run.values, vec![0.0, 0.5, 0.75]);

        // u64 label propagation on the same structure
        let run = eng.run_typed(&LabelProp, 100).unwrap();
        assert_eq!(run.values, vec![0, 0, 0]);
    }
}
