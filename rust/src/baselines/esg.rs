//! ESG — the edge-centric scatter-gather model of **X-Stream** (Roy et al.,
//! SOSP'13), as analyzed in paper §III-B.
//!
//! Phase 1 (scatter): stream each partition's out-edges; for every edge
//! emit an update `(dst, contribution)` appended to the destination
//! partition's update file.  Reads `C·V + D·E`, writes `C·E`.
//!
//! Phase 2 (gather): stream each partition's update file, reduce+apply into
//! the partition's vertex chunk.  Reads `C·E`, writes `C·V`.
//!
//! Everything here is real file traffic — X-Stream's whole point is that
//! sequential streams beat random access, and that is what the files do.
//! Contributions are `V::BYTES` wide (the update record is `dst` + one
//! lane element), and the edge files carry the weight lane when present.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::baselines::common::{self, BaselineRun, OocEngine};
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::storage::io;
use crate::storage::prefetch::ReadAhead;

/// Number of streaming partitions (X-Stream sizes these to fit vertex state
/// in memory; scaled for the container datasets).
const PARTITIONS: usize = 8;

pub struct EsgEngine {
    dir: PathBuf,
    bounds: Vec<VertexId>,
    num_vertices: usize,
    num_edges: u64,
    out_deg: Vec<u32>,
    weighted: bool,
    adaptive_order: bool,
}

impl EsgEngine {
    pub fn new(dir: PathBuf) -> Self {
        Self {
            dir,
            bounds: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            out_deg: Vec::new(),
            weighted: false,
            adaptive_order: false,
        }
    }

    /// Gather destination partitions hottest-first (previous iteration's
    /// changed counts) instead of in file order.  Only the gather phase
    /// reorders: the scatter phase's partition order fixes the
    /// concatenation order of each update file, which *is* the float-Sum
    /// fold order, so it stays file-ordered to keep results bit-identical.
    pub fn set_adaptive_order(&mut self, on: bool) {
        self.adaptive_order = on;
    }

    fn edges_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("esg_edges_{i:02}.bin"))
    }

    fn chunk_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("esg_chunk_{i:02}.bin"))
    }

    fn updates_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("esg_updates_{i:02}.bin"))
    }

    fn num_parts(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Memory model with an explicit lane width `c`: one partition's
    /// vertices — C·V/P.
    fn memory_estimate_lane(&self, c: u64) -> u64 {
        c * self.num_vertices as u64 / self.num_parts().max(1) as u64
    }

    /// Typed run over any value lane (see trait docs).
    pub fn run_typed<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let n = self.num_vertices;
        let p = self.num_parts();
        let ctx = ProgramContext { num_vertices: n as u64 };
        let t0 = Instant::now();

        // vertex chunks initialized on disk
        let init: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        for i in 0..p {
            let (lo, hi) = (self.bounds[i] as usize, self.bounds[i + 1] as usize);
            common::write_values(&self.chunk_path(i), &init[lo..hi])?;
        }
        let load_wall = t0.elapsed();

        let io_start = io::snapshot();
        let mut iter_walls = Vec::new();
        let mut iter_io = Vec::new();
        let mut edges_processed = 0u64;
        let mut sched = common::HeatSchedule::new(p, self.adaptive_order);

        for _iter in 0..max_iters {
            let t_iter = Instant::now();
            let io_before = io::snapshot();
            let mut changed = false;

            // --- phase 1: scatter ---------------------------------------
            // chunk/edge streams read ahead of the scatter compute (same
            // files, same order — byte accounting is unchanged)
            let mut scatter_stream = ReadAhead::new(
                (0..p)
                    .flat_map(|i| [self.chunk_path(i), self.edges_path(i)])
                    .collect(),
                common::READ_AHEAD_DEPTH,
            );
            let mut update_bufs: Vec<Vec<u8>> = vec![Vec::new(); p];
            for i in 0..p {
                // C·V/P
                let chunk_buf = common::next_buf(&mut scatter_stream, "esg chunk")?;
                let chunk: Vec<V> = common::values_from_bytes(&chunk_buf)?;
                let lo = self.bounds[i];
                // D·E/P
                let (edges, weights) = common::edges_from_bytes_w(
                    &common::next_buf(&mut scatter_stream, "esg edges")?,
                    self.weighted,
                )?;
                for (k, (s, d)) in edges.into_iter().enumerate() {
                    let w = if self.weighted { weights[k] } else { 1.0 };
                    let contrib =
                        app.gather(chunk[(s - lo) as usize], self.out_deg[s as usize], w);
                    let target = common::chunk_of(&self.bounds, d);
                    encode_update(&mut update_bufs[target], d, contrib);
                }
                edges_processed += self.num_edges / p as u64;
            }
            for (i, buf) in update_bufs.iter().enumerate() {
                io::write_file(&self.updates_path(i), buf)?; // C·E write
            }

            // --- phase 2: gather (hottest destination first under
            // adaptive order; each partition folds only its own update
            // file and writes only its own chunk, so order is free) ------
            let order = sched.order();
            let mut gather_stream = ReadAhead::new(
                order
                    .iter()
                    .flat_map(|&i| [self.chunk_path(i), self.updates_path(i)])
                    .collect(),
                common::READ_AHEAD_DEPTH,
            );
            for &i in &order {
                let (lo, hi) = (self.bounds[i], self.bounds[i + 1]);
                let mut chunk: Vec<V> =
                    common::values_from_bytes(&common::next_buf(&mut gather_stream, "esg chunk")?)?;
                let updates = common::next_buf(&mut gather_stream, "esg updates")?; // C·E read
                let reduce = app.reduce();
                let mut acc = vec![reduce.identity::<V>(); (hi - lo) as usize];
                for (d, contrib) in decode_updates::<V>(&updates) {
                    let k = (d - lo) as usize;
                    acc[k] = reduce.combine(acc[k], contrib);
                }
                let mut part_changed = 0u64;
                for k in 0..acc.len() {
                    let old = chunk[k];
                    let nv = app.apply(acc[k], old, &ctx);
                    if V::changed(old, nv, 0.0) {
                        changed = true;
                        part_changed += 1;
                    }
                    chunk[k] = nv;
                }
                sched.record(i, part_changed);
                common::write_values(&self.chunk_path(i), &chunk)?; // C·V write
            }

            sched.advance();
            iter_walls.push(t_iter.elapsed());
            iter_io.push(io::snapshot().since(&io_before));
            if !changed {
                break;
            }
        }

        // collect final values
        let mut values = Vec::with_capacity(n);
        for i in 0..p {
            values.extend(common::read_values::<V>(&self.chunk_path(i))?);
        }
        Ok(BaselineRun {
            values,
            iter_walls,
            load_wall,
            total_wall: t0.elapsed(),
            io: io::snapshot().since(&io_start),
            iter_io,
            memory_bytes: self.memory_estimate_lane(V::BYTES as u64),
            edges_processed,
        })
    }
}

/// An update record: destination vertex + contribution (4 + `V::BYTES`).
fn encode_update<V: VertexValue>(buf: &mut Vec<u8>, dst: VertexId, contrib: V) {
    buf.extend_from_slice(&dst.to_le_bytes());
    contrib.write_le(buf);
}

fn decode_updates<V: VertexValue>(buf: &[u8]) -> impl Iterator<Item = (VertexId, V)> + '_ {
    buf.chunks_exact(4 + V::BYTES).map(|c| {
        (
            u32::from_le_bytes(c[0..4].try_into().unwrap()),
            V::read_le(&c[4..]),
        )
    })
}

impl OocEngine for EsgEngine {
    fn name(&self) -> &'static str {
        "esg(x-stream)"
    }

    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()> {
        common::fresh_dir(&self.dir)?;
        let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
        self.out_deg = degrees.out_deg;
        self.bounds = common::equal_chunks(num_vertices, PARTITIONS);
        self.num_vertices = num_vertices;
        self.num_edges = edges.len() as u64;
        self.weighted = !weights.is_empty();
        // out-edges partitioned by SOURCE (X-Stream's streaming partitions)
        let p = self.num_parts();
        let (buckets, wbuckets) =
            common::bucket_weighted(&self.bounds, p, edges, weights, |(s, _)| s);
        for (i, b) in buckets.iter().enumerate() {
            common::write_edges_w(&self.edges_path(i), b, &wbuckets[i])?;
        }
        Ok(())
    }

    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun> {
        self.run_typed(app, max_iters)
    }

    /// X-Stream keeps one partition's vertices in memory: C·V/P (f32 C=4).
    fn memory_estimate(&self) -> u64 {
        self.memory_estimate_lane(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{MaxDeg, Sssp, Wcc};
    use crate::graph::generator;

    #[test]
    fn esg_min_apps_converge() {
        let edges = generator::erdos_renyi(120, 700, 13);
        let mut eng = EsgEngine::new(
            std::env::temp_dir().join(format!("gmp_esg_t_{}", std::process::id())),
        );
        eng.prepare(&edges, 120).unwrap();

        let run = eng.run(&Sssp { source: 0 }, 200).unwrap();
        // reference
        let ctx = ProgramContext { num_vertices: 120 };
        let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); 120];
        let mut out_deg = vec![0u32; 120];
        for &(s, d) in &edges {
            in_adj[d as usize].push(s);
            out_deg[s as usize] += 1;
        }
        let app = Sssp { source: 0 };
        let mut vals: Vec<f32> = (0..120).map(|v| app.init(v, &ctx)).collect();
        for _ in 0..200 {
            let next: Vec<f32> = (0..120u32)
                .map(|v| app.update(v, &in_adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
            if next == vals {
                break;
            }
            vals = next;
        }
        for (i, (a, b)) in run.values.iter().zip(&vals).enumerate() {
            assert!(
                (a.is_infinite() && b.is_infinite()) || a == b,
                "sssp v{i}: {a} vs {b}"
            );
        }

        let run = eng.run(&Wcc, 200).unwrap();
        assert_eq!(run.values.len(), 120);
        // write volume should exceed VSW's zero but stay below PSW's
        assert!(run.io.bytes_written > 0);
    }

    #[test]
    fn esg_typed_u32_max_monoid_converges() {
        // star: hub 0 with high out-degree feeding a path
        let edges = vec![(0u32, 1u32), (0, 2), (0, 3), (3, 4)];
        let mut eng = EsgEngine::new(
            std::env::temp_dir().join(format!("gmp_esg_u32_{}", std::process::id())),
        );
        eng.prepare(&edges, 5).unwrap();
        let run = eng.run_typed(&MaxDeg, 50).unwrap();
        // out_deg = [3,0,0,1,0]; everything downstream of 0 sees 3
        assert_eq!(run.values, vec![0, 3, 3, 3, 3]);
    }
}
