//! VSP — the vertex-centric streamlined processing model of **VENUS**
//! (Cheng et al., ICDE'15), as analyzed in paper §III-C.
//!
//! VENUS splits vertices into P intervals; each interval has a **g-shard**
//! (all edges with destination in the interval — structure only, no edge
//! values) and a **v-shard** (the set of vertices appearing in the g-shard:
//! the interval itself plus external sources).  One iteration streams each
//! g-shard while keeping only its v-shard's values in memory:
//!
//! * read: v-shard values `C(1+δ)·V` + g-shard structure `D·E`
//! * write: updated interval values `C·V` (no edge writes — the paper's key
//!   point about VENUS vs GraphChi)
//!
//! VENUS is closed-source; this reimplementation follows the paper's
//! description + Table II.  The g-shards and the final value writes are
//! real files; per-v-shard value gathers (VENUS serves them from its
//! materialized view) are accounted virtually at `C · |v-shard|` per shard.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::baselines::common::{self, BaselineRun, OocEngine};
use crate::graph::csr::Csr;
use crate::graph::{Degrees, Edge, VertexId, Weight};
use crate::sharding::intervals::compute_intervals;
use crate::storage::prefetch::ReadAhead;
use crate::storage::{io, shardfile};

const EDGES_PER_SHARD: usize = 1 << 14;

pub struct VspEngine {
    dir: PathBuf,
    intervals: Vec<VertexId>,
    /// v-shard id lists (external sources per shard), from preprocessing.
    vshard_sizes: Vec<usize>,
    num_vertices: usize,
    num_edges: u64,
    out_deg: Vec<u32>,
    adaptive_order: bool,
}

impl VspEngine {
    pub fn new(dir: PathBuf) -> Self {
        Self {
            dir,
            intervals: Vec::new(),
            vshard_sizes: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            out_deg: Vec::new(),
            adaptive_order: false,
        }
    }

    /// Issue g-shards hottest-first (previous iteration's changed-vertex
    /// counts) instead of in file order; each shard writes only its own
    /// interval from the previous view, so results are identical.
    pub fn set_adaptive_order(&mut self, on: bool) {
        self.adaptive_order = on;
    }

    fn gshard_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("vsp_gshard_{i:04}.bin"))
    }

    fn values_path(&self) -> PathBuf {
        self.dir.join("vsp_values.bin")
    }

    fn num_shards(&self) -> usize {
        self.intervals.len().saturating_sub(1)
    }

    /// δ ≈ (1 - e^(-d_avg/P))·P — Table II's v-shard inflation factor.
    pub fn delta(&self) -> f64 {
        let p = self.num_shards().max(1) as f64;
        let d_avg = self.num_edges as f64 / self.num_vertices.max(1) as f64;
        (1.0 - (-d_avg / p).exp()) * p
    }

    /// Memory model with an explicit lane width `c`: one v-shard + its
    /// updates — C(2+δ)·V/P.
    fn memory_estimate_lane(&self, c: u64) -> u64 {
        let p = self.num_shards().max(1) as f64;
        (c as f64 * (2.0 + self.delta()) * self.num_vertices as f64 / p) as u64
    }

    /// Typed run over any value lane (see trait docs).
    pub fn run_typed<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &mut self,
        app: &P,
        max_iters: usize,
    ) -> Result<BaselineRun<V>> {
        let n = self.num_vertices;
        let p = self.num_shards();
        let ctx = ProgramContext { num_vertices: n as u64 };
        let t0 = Instant::now();

        let init: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        common::write_values(&self.values_path(), &init)?;
        let load_wall = t0.elapsed();

        let io_start = io::snapshot();
        let mut iter_walls = Vec::new();
        let mut iter_io = Vec::new();
        let mut edges_processed = 0u64;
        let mut sched = common::HeatSchedule::new(p, self.adaptive_order);

        // VENUS's materialized view: the current value array, from which
        // v-shard reads are served (accounted virtually below)
        let mut view = init;

        for _iter in 0..max_iters {
            let t_iter = Instant::now();
            let io_before = io::snapshot();
            let mut changed = false;
            let mut new_view = view.clone();

            // g-shard structure streams ahead of the per-shard compute
            // (hottest-first under adaptive order; same files, same bytes)
            let order = sched.order();
            let mut stream = ReadAhead::new(
                order.iter().map(|&i| self.gshard_path(i)).collect(),
                common::READ_AHEAD_DEPTH,
            );
            for &i in &order {
                // D·E real
                let csr = shardfile::from_bytes(&common::next_buf(&mut stream, "vsp gshard")?)?;
                // v-shard value gather: C·|v-shard| virtual read (C = the
                // lane width; f32 reproduces the paper's C=4)
                io::account_virtual_read((V::BYTES * self.vshard_sizes[i]) as u64);
                let reduce = app.reduce();
                let mut shard_changed = 0u64;
                for (row, (v, _)) in csr.iter_rows().enumerate() {
                    let s = csr.row_ptr[row] as usize;
                    let e = csr.row_ptr[row + 1] as usize;
                    let mut acc = reduce.identity::<V>();
                    for k in s..e {
                        let u = csr.col[k] as usize;
                        acc = reduce.combine(
                            acc,
                            app.gather(view[u], self.out_deg[u], csr.weight(k)),
                        );
                    }
                    let old = view[v as usize];
                    let nv = app.apply(acc, old, &ctx);
                    if V::changed(old, nv, 0.0) {
                        changed = true;
                        shard_changed += 1;
                    }
                    new_view[v as usize] = nv;
                }
                sched.record(i, shard_changed);
                edges_processed += csr.num_edges() as u64;
            }

            // write updated vertices: C·V real (VENUS's only write)
            common::write_values(&self.values_path(), &new_view)?;
            view = new_view;

            sched.advance();
            iter_walls.push(t_iter.elapsed());
            iter_io.push(io::snapshot().since(&io_before));
            if !changed {
                break;
            }
        }

        let values: Vec<V> = common::read_values(&self.values_path())?;
        Ok(BaselineRun {
            values,
            iter_walls,
            load_wall,
            total_wall: t0.elapsed(),
            io: io::snapshot().since(&io_start),
            iter_io,
            memory_bytes: self.memory_estimate_lane(V::BYTES as u64),
            edges_processed,
        })
    }
}

impl OocEngine for VspEngine {
    fn name(&self) -> &'static str {
        "vsp(venus)"
    }

    fn prepare_weighted(
        &mut self,
        edges: &[Edge],
        weights: &[Weight],
        num_vertices: usize,
    ) -> Result<()> {
        common::fresh_dir(&self.dir)?;
        let degrees = Degrees::from_edges(num_vertices, edges.iter().copied());
        self.out_deg = degrees.out_deg;
        self.intervals = compute_intervals(&degrees.in_deg, EDGES_PER_SHARD);
        self.num_vertices = num_vertices;
        self.num_edges = edges.len() as u64;
        let p = self.num_shards();
        let (buckets, wbuckets) =
            common::bucket_weighted(&self.intervals, p, edges, weights, |(_, d)| d);
        self.vshard_sizes.clear();
        for (i, bucket) in buckets.iter().enumerate() {
            let csr = Csr::from_edges_weighted(
                self.intervals[i],
                self.intervals[i + 1],
                bucket,
                &wbuckets[i],
            );
            // v-shard = interval + distinct external sources
            let mut srcs: Vec<u32> = csr.col.clone();
            srcs.sort_unstable();
            srcs.dedup();
            let interval_len = (csr.hi - csr.lo) as usize;
            let external = srcs
                .iter()
                .filter(|&&s| s < csr.lo || s >= csr.hi)
                .count();
            self.vshard_sizes.push(interval_len + external);
            shardfile::save(&csr, &self.gshard_path(i))?;
        }
        Ok(())
    }

    fn run(&mut self, app: &dyn VertexProgram, max_iters: usize) -> Result<BaselineRun> {
        self.run_typed(app, max_iters)
    }

    /// VENUS keeps one v-shard + its updates in memory: C(2+δ)·V/P
    /// (f32 C=4).
    fn memory_estimate(&self) -> u64 {
        self.memory_estimate_lane(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{LabelProp, Wcc};
    use crate::graph::generator;

    #[test]
    fn vsp_wcc_converges() {
        // symmetrize so WCC labels are true components
        let mut edges = generator::erdos_renyi(100, 300, 17);
        let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
        edges.extend(rev);
        let mut eng = VspEngine::new(
            std::env::temp_dir().join(format!("gmp_vsp_t_{}", std::process::id())),
        );
        eng.prepare(&edges, 100).unwrap();
        let run = eng.run(&Wcc, 100).unwrap();
        // labels must be a fixpoint: every vertex equals min over in-nbrs+self
        let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); 100];
        for &(s, d) in &edges {
            in_adj[d as usize].push(s);
        }
        for v in 0..100usize {
            let mut m = run.values[v];
            for &u in &in_adj[v] {
                m = m.min(run.values[u as usize]);
            }
            assert_eq!(m, run.values[v], "not a fixpoint at {v}");
        }
        // VSP writes only vertices: far fewer bytes written than read
        assert!(run.io.bytes_written * 4 < run.io.bytes_read);

        // the u64 label lane reaches the same component structure
        let typed = eng.run_typed(&LabelProp, 100).unwrap();
        for (v, &label) in typed.values.iter().enumerate() {
            assert_eq!(label as f32, run.values[v], "lane mismatch at {v}");
        }
    }

    #[test]
    fn delta_is_bounded_by_p() {
        let mut eng = VspEngine::new(std::env::temp_dir().join("gmp_vsp_delta"));
        let edges = generator::erdos_renyi(500, 5000, 3);
        eng.prepare(&edges, 500).unwrap();
        let delta = eng.delta();
        assert!(delta > 0.0 && delta <= eng.num_shards() as f64);
    }
}
