//! Synthetic graph generators — the dataset substitute (DESIGN.md §3).
//!
//! * [`rmat`] — R-MAT (Chakrabarti et al.) recursive-matrix power-law
//!   graphs; with the classic `(a,b,c,d) = (0.57,0.19,0.19,0.05)` the
//!   in-degree distribution matches the heavy skew of the paper's webgraphs
//!   (Twitter max in-deg 0.7M at 42M vertices → same ratio here).
//! * [`erdos_renyi`] — uniform G(n, m), the no-skew control used by tests.
//! * [`grid2d`] — 2-D lattice "road network" for the SSSP example (long
//!   diameter, low degree — the opposite regime from webgraphs).

use crate::graph::{Edge, VertexId, Weight};
use crate::util::hash::hash64_seeded;
use crate::util::rng::Xoshiro256;

/// Deterministic synthetic edge weights for a generated graph: a pure
/// function of `(src, dst, seed)`, so every engine, driver and the Python
/// fixture port derive the identical weight for the same edge.  Weights are
/// dyadic rationals in `{0.25, 0.5, …, 2.0}` — exactly representable in
/// `f32`, which keeps cross-engine comparisons bit-sharp.
pub fn synth_weights(edges: &[Edge], seed: u64) -> Vec<Weight> {
    edges
        .iter()
        .map(|&(s, d)| {
            let h = hash64_seeded(((s as u64) << 32) | d as u64, seed);
            (1 + (h & 7)) as Weight * 0.25
        })
        .collect()
}

/// R-MAT parameters.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Probability of noise-perturbing quadrant probabilities per level
    /// (avoids the striping artifacts of pure R-MAT).
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        Self { a: 0.57, b: 0.19, c: 0.19, noise: 0.1 }
    }
}

/// Generate an R-MAT graph with `2^scale` vertices and `num_edges` edges.
/// Self-loops are kept (webgraphs have them); duplicate edges are kept too —
/// the preprocessing pipeline treats the input as a multigraph, like the
/// paper's CSV ingestion.
pub fn rmat(scale: u32, num_edges: u64, params: RmatParams, seed: u64) -> Vec<Edge> {
    let n: u64 = 1 << scale;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut edges = Vec::with_capacity(num_edges as usize);
    for _ in 0..num_edges {
        let (mut x0, mut x1) = (0u64, n);
        let (mut y0, mut y1) = (0u64, n);
        let (mut a, mut b, mut c) = (params.a, params.b, params.c);
        while x1 - x0 > 1 || y1 - y0 > 1 {
            let r = rng.next_f64();
            let (right, down) = if r < a {
                (false, false)
            } else if r < a + b {
                (true, false)
            } else if r < a + b + c {
                (false, true)
            } else {
                (true, true)
            };
            let xm = (x0 + x1) / 2;
            let ym = (y0 + y1) / 2;
            if x1 - x0 > 1 {
                if right {
                    x0 = xm;
                } else {
                    x1 = xm;
                }
            }
            if y1 - y0 > 1 {
                if down {
                    y0 = ym;
                } else {
                    y1 = ym;
                }
            }
            if params.noise > 0.0 {
                // multiplicative noise keeps expectation, breaks striping
                let jitter = |p: f64, r: &mut Xoshiro256| {
                    (p * (1.0 - params.noise + 2.0 * params.noise * r.next_f64())).max(1e-3)
                };
                a = jitter(a, &mut rng);
                b = jitter(b, &mut rng);
                c = jitter(c, &mut rng);
                let s = a + b + c;
                if s >= 0.999 {
                    let k = 0.999 / s;
                    a *= k;
                    b *= k;
                    c *= k;
                }
            }
        }
        edges.push((x0 as VertexId, y0 as VertexId));
    }
    edges
}

/// Uniform random G(n, m) digraph.
pub fn erdos_renyi(num_vertices: usize, num_edges: u64, seed: u64) -> Vec<Edge> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..num_edges)
        .map(|_| {
            (
                rng.range_usize(0, num_vertices) as VertexId,
                rng.range_usize(0, num_vertices) as VertexId,
            )
        })
        .collect()
}

/// 2-D lattice with bidirectional edges between 4-neighbors plus a few
/// random "highway" shortcuts: a road-network-like workload for SSSP.
pub fn grid2d(rows: usize, cols: usize, shortcuts: usize, seed: u64) -> Vec<Edge> {
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    let mut edges = Vec::with_capacity(rows * cols * 4 + shortcuts * 2);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
                edges.push((id(r, c + 1), id(r, c)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
                edges.push((id(r + 1, c), id(r, c)));
            }
        }
    }
    let n = rows * cols;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for _ in 0..shortcuts {
        let a = rng.range_usize(0, n) as VertexId;
        let b = rng.range_usize(0, n) as VertexId;
        edges.push((a, b));
        edges.push((b, a));
    }
    edges
}

/// Number of vertices implied by `rmat(scale, ..)`.
pub fn rmat_vertices(scale: u32) -> usize {
    1usize << scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Degrees;

    #[test]
    fn rmat_shapes_and_determinism() {
        let e1 = rmat(10, 5000, RmatParams::default(), 7);
        let e2 = rmat(10, 5000, RmatParams::default(), 7);
        assert_eq!(e1.len(), 5000);
        assert_eq!(e1, e2, "same seed, same graph");
        assert!(e1.iter().all(|&(s, d)| (s as usize) < 1024 && (d as usize) < 1024));
        let e3 = rmat(10, 5000, RmatParams::default(), 8);
        assert_ne!(e1, e3, "different seed differs");
    }

    #[test]
    fn rmat_is_power_law_skewed() {
        let scale = 12;
        let edges = rmat(scale, 40_000, RmatParams::default(), 42);
        let d = Degrees::from_edges(1 << scale, edges.iter().copied());
        let max_in = *d.in_deg.iter().max().unwrap();
        let avg = 40_000.0 / (1 << scale) as f64;
        // power-law: max degree far above average (paper's graphs: 1000x+)
        assert!(
            (max_in as f64) > 20.0 * avg,
            "max in-degree {max_in} not skewed vs avg {avg}"
        );
    }

    #[test]
    fn erdos_renyi_is_not_skewed() {
        let edges = erdos_renyi(4096, 40_000, 1);
        let d = Degrees::from_edges(4096, edges.iter().copied());
        let max_in = *d.in_deg.iter().max().unwrap();
        assert!(max_in < 50, "ER max in-degree should be near-mean, got {max_in}");
    }

    #[test]
    fn synth_weights_deterministic_dyadic_positive() {
        let edges = rmat(8, 1000, RmatParams::default(), 3);
        let w1 = synth_weights(&edges, 11);
        let w2 = synth_weights(&edges, 11);
        assert_eq!(w1, w2, "same seed, same weights");
        assert_eq!(w1.len(), edges.len());
        assert!(w1.iter().all(|&w| (0.25..=2.0).contains(&w)));
        // dyadic: 4*w is a small integer, exactly representable in f32
        assert!(w1.iter().all(|&w| (w * 4.0).fract() == 0.0));
        let w3 = synth_weights(&edges, 12);
        assert_ne!(w1, w3, "different seed differs");
    }

    #[test]
    fn grid_has_expected_edge_count() {
        let e = grid2d(10, 10, 5, 3);
        // 2 * (rows*(cols-1) + cols*(rows-1)) directed + 2*shortcuts
        assert_eq!(e.len(), 2 * (10 * 9 + 10 * 9) + 10);
        assert!(e.iter().all(|&(s, d)| (s as usize) < 100 && (d as usize) < 100));
    }
}
