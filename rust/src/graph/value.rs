//! Typed vertex-state lanes — the `VertexValue` POD trait.
//!
//! The paper's user API (§II-C, Algorithm 2) is `Update(v, SrcVertexArray)`
//! over an *arbitrary* vertex array; nothing in the model fixes the element
//! type to `f32`.  This module opens that axis: a vertex program's state is
//! any [`VertexValue`] — a plain-old-data scalar with a little-endian wire
//! format, the monoid elements the engine's reductions need (zero/min/max
//! identities, add/min/max combines), and the convergence predicate the
//! active-set scan uses.  Four lanes are provided: `u32`, `u64`, `f32`,
//! `f64`.
//!
//! Everything downstream is generic over the lane: `storage::format` /
//! `storage::vertexinfo` serialize any lane, `engine::backend`'s
//! monomorphized gather loops fold any lane, and the baselines' raw value
//! files hold `V::BYTES` per vertex.  [`AnyValues`] is the lane-tagged
//! dynamic counterpart used where a single runtime type must carry any lane
//! (the CLI, persisted vertex values).

use anyhow::{bail, ensure, Result};

use crate::graph::Weight;

/// Which scalar lane a value belongs to.  The `tag` is the on-disk
/// discriminant (vertexinfo v2); never renumber existing lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    U32,
    U64,
    F32,
    F64,
}

impl Lane {
    /// All lanes, for fuzz/conformance sweeps.
    pub const ALL: [Lane; 4] = [Lane::U32, Lane::U64, Lane::F32, Lane::F64];

    pub fn name(self) -> &'static str {
        match self {
            Lane::U32 => "u32",
            Lane::U64 => "u64",
            Lane::F32 => "f32",
            Lane::F64 => "f64",
        }
    }

    /// On-disk discriminant.
    pub fn tag(self) -> u32 {
        match self {
            Lane::U32 => 1,
            Lane::U64 => 2,
            Lane::F32 => 3,
            Lane::F64 => 4,
        }
    }

    pub fn from_tag(tag: u32) -> Result<Lane> {
        Ok(match tag {
            1 => Lane::U32,
            2 => Lane::U64,
            3 => Lane::F32,
            4 => Lane::F64,
            other => bail!("unknown value-lane tag {other}"),
        })
    }

    /// Bytes per element in this lane.
    pub fn bytes(self) -> usize {
        match self {
            Lane::U32 | Lane::F32 => 4,
            Lane::U64 | Lane::F64 => 8,
        }
    }
}

/// A plain-old-data vertex value: fixed-width little-endian wire format,
/// the monoid pieces the engine's `Sum`/`Min`/`Max` reductions need, and
/// the convergence predicate for active-set tracking.
///
/// The `v*`-prefixed method names avoid resolution clashes with the
/// `std::ops`/`Ord` methods of the same spelling at call sites that import
/// both.
pub trait VertexValue:
    Copy + PartialEq + Send + Sync + std::fmt::Debug + std::fmt::Display + 'static
{
    const LANE: Lane;
    /// Wire width; equals `Self::LANE.bytes()`.
    const BYTES: usize;
    /// Whether `vadd` is exactly associative (integer wrapping add), so a
    /// `Sum` reduction may reassociate across SIMD accumulators without
    /// changing any bit.  Float addition is order-sensitive: float lanes
    /// keep the strict left-to-right fold (`engine::simd::sum_map`).
    const SUM_REASSOCIATES: bool;

    /// Additive identity (`Reduce::Sum`).
    fn vzero() -> Self;
    /// Unit step (`GatherKind::PlusOne`).
    fn vone() -> Self;
    /// `Reduce::Min`'s identity (`+inf` for floats, `MAX` for ints).
    fn vmax_value() -> Self;
    /// `Reduce::Max`'s identity (`-inf` for floats, `MIN` for ints).
    fn vmin_value() -> Self;

    fn vadd(self, other: Self) -> Self;
    fn vmin(self, other: Self) -> Self;
    fn vmax(self, other: Self) -> Self;

    /// Lift an edge weight into this lane (`GatherKind::PlusWeight`).
    fn from_weight(w: Weight) -> Self;
    /// `self / deg` — PageRank's per-out-edge share.  Integer lanes use
    /// integer division (well-defined, though no integer app divides).
    /// `deg` must be non-zero.
    fn div_deg(self, deg: u32) -> Self;

    /// Did the value change beyond `tol`?  Float lanes treat two infinities
    /// as unchanged and compare `|new - old| > tol` (bit-compatible with
    /// the engine's historical f32 predicate); integer lanes ignore `tol`
    /// and compare equality.
    fn changed(old: Self, new: Self, tol: f64) -> bool;

    /// Lossy f64 view, for tolerance-based comparisons and display.
    fn approx_f64(self) -> f64;

    /// Append the little-endian wire form.
    fn write_le(self, out: &mut Vec<u8>);
    /// Read from exactly `Self::BYTES` bytes.
    fn read_le(buf: &[u8]) -> Self;
}

impl VertexValue for u32 {
    const LANE: Lane = Lane::U32;
    const BYTES: usize = 4;
    const SUM_REASSOCIATES: bool = true;

    fn vzero() -> Self {
        0
    }
    fn vone() -> Self {
        1
    }
    fn vmax_value() -> Self {
        u32::MAX
    }
    fn vmin_value() -> Self {
        u32::MIN
    }
    fn vadd(self, other: Self) -> Self {
        self.wrapping_add(other)
    }
    fn vmin(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    fn vmax(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    fn from_weight(w: Weight) -> Self {
        w as u32
    }
    fn div_deg(self, deg: u32) -> Self {
        self / deg
    }
    fn changed(old: Self, new: Self, _tol: f64) -> bool {
        old != new
    }
    fn approx_f64(self) -> f64 {
        self as f64
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        u32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl VertexValue for u64 {
    const LANE: Lane = Lane::U64;
    const BYTES: usize = 8;
    const SUM_REASSOCIATES: bool = true;

    fn vzero() -> Self {
        0
    }
    fn vone() -> Self {
        1
    }
    fn vmax_value() -> Self {
        u64::MAX
    }
    fn vmin_value() -> Self {
        u64::MIN
    }
    fn vadd(self, other: Self) -> Self {
        self.wrapping_add(other)
    }
    fn vmin(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    fn vmax(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    fn from_weight(w: Weight) -> Self {
        w as u64
    }
    fn div_deg(self, deg: u32) -> Self {
        self / deg as u64
    }
    fn changed(old: Self, new: Self, _tol: f64) -> bool {
        old != new
    }
    fn approx_f64(self) -> f64 {
        self as f64
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        u64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

impl VertexValue for f32 {
    const LANE: Lane = Lane::F32;
    const BYTES: usize = 4;
    const SUM_REASSOCIATES: bool = false;

    fn vzero() -> Self {
        0.0
    }
    fn vone() -> Self {
        1.0
    }
    fn vmax_value() -> Self {
        f32::INFINITY
    }
    fn vmin_value() -> Self {
        f32::NEG_INFINITY
    }
    fn vadd(self, other: Self) -> Self {
        self + other
    }
    fn vmin(self, other: Self) -> Self {
        f32::min(self, other)
    }
    fn vmax(self, other: Self) -> Self {
        f32::max(self, other)
    }
    fn from_weight(w: Weight) -> Self {
        w
    }
    fn div_deg(self, deg: u32) -> Self {
        self / deg as f32
    }
    fn changed(old: Self, new: Self, tol: f64) -> bool {
        if old.is_infinite() && new.is_infinite() {
            return false;
        }
        (new - old).abs() > tol as f32
    }
    fn approx_f64(self) -> f64 {
        self as f64
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        f32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl VertexValue for f64 {
    const LANE: Lane = Lane::F64;
    const BYTES: usize = 8;
    const SUM_REASSOCIATES: bool = false;

    fn vzero() -> Self {
        0.0
    }
    fn vone() -> Self {
        1.0
    }
    fn vmax_value() -> Self {
        f64::INFINITY
    }
    fn vmin_value() -> Self {
        f64::NEG_INFINITY
    }
    fn vadd(self, other: Self) -> Self {
        self + other
    }
    fn vmin(self, other: Self) -> Self {
        f64::min(self, other)
    }
    fn vmax(self, other: Self) -> Self {
        f64::max(self, other)
    }
    fn from_weight(w: Weight) -> Self {
        w as f64
    }
    fn div_deg(self, deg: u32) -> Self {
        self / deg as f64
    }
    fn changed(old: Self, new: Self, tol: f64) -> bool {
        if old.is_infinite() && new.is_infinite() {
            return false;
        }
        (new - old).abs() > tol
    }
    fn approx_f64(self) -> f64 {
        self
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(buf: &[u8]) -> Self {
        f64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

/// A lane-tagged value vector: the dynamic counterpart of `Vec<V>` used
/// where one runtime type must carry any lane (persisted vertex values,
/// CLI results).
#[derive(Debug, Clone, PartialEq)]
pub enum AnyValues {
    U32(Vec<u32>),
    U64(Vec<u64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl Default for AnyValues {
    fn default() -> Self {
        AnyValues::F32(Vec::new())
    }
}

impl From<Vec<u32>> for AnyValues {
    fn from(v: Vec<u32>) -> Self {
        AnyValues::U32(v)
    }
}
impl From<Vec<u64>> for AnyValues {
    fn from(v: Vec<u64>) -> Self {
        AnyValues::U64(v)
    }
}
impl From<Vec<f32>> for AnyValues {
    fn from(v: Vec<f32>) -> Self {
        AnyValues::F32(v)
    }
}
impl From<Vec<f64>> for AnyValues {
    fn from(v: Vec<f64>) -> Self {
        AnyValues::F64(v)
    }
}

impl AnyValues {
    pub fn lane(&self) -> Lane {
        match self {
            AnyValues::U32(_) => Lane::U32,
            AnyValues::U64(_) => Lane::U64,
            AnyValues::F32(_) => Lane::F32,
            AnyValues::F64(_) => Lane::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            AnyValues::U32(v) => v.len(),
            AnyValues::U64(v) => v.len(),
            AnyValues::F32(v) => v.len(),
            AnyValues::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lossy f64 view of element `i` (display / tolerance comparisons).
    pub fn approx_f64(&self, i: usize) -> f64 {
        match self {
            AnyValues::U32(v) => v[i].approx_f64(),
            AnyValues::U64(v) => v[i].approx_f64(),
            AnyValues::F32(v) => v[i].approx_f64(),
            AnyValues::F64(v) => v[i].approx_f64(),
        }
    }

    /// Bit-exact text form of element `i` (float lanes as IEEE bit
    /// patterns, integer lanes as decimal) — `None` past the end.  One
    /// rendering shared by `--dump-values` and the serve protocol, so the
    /// two can be compared byte for byte.
    pub fn render_bits(&self, i: usize) -> Option<String> {
        match self {
            AnyValues::U32(v) => v.get(i).map(|x| format!("{x}")),
            AnyValues::U64(v) => v.get(i).map(|x| format!("{x}")),
            AnyValues::F32(v) => v.get(i).map(|x| format!("{:08x}", x.to_bits())),
            AnyValues::F64(v) => v.get(i).map(|x| format!("{:016x}", x.to_bits())),
        }
    }

    /// [`Self::render_bits`] over the whole vector, one line per vertex
    /// with a trailing newline on each (the `--dump-values` file format).
    pub fn render_bits_all(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        match self {
            AnyValues::U32(v) => v.iter().for_each(|x| {
                let _ = writeln!(s, "{x}");
            }),
            AnyValues::U64(v) => v.iter().for_each(|x| {
                let _ = writeln!(s, "{x}");
            }),
            AnyValues::F32(v) => v.iter().for_each(|x| {
                let _ = writeln!(s, "{:08x}", x.to_bits());
            }),
            AnyValues::F64(v) => v.iter().for_each(|x| {
                let _ = writeln!(s, "{:016x}", x.to_bits());
            }),
        }
        s
    }

    /// Append the wire form: `[lane tag u32][count u64][raw LE elements]`.
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.lane().tag().to_le_bytes());
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        match self {
            AnyValues::U32(v) => v.iter().for_each(|x| x.write_le(out)),
            AnyValues::U64(v) => v.iter().for_each(|x| x.write_le(out)),
            AnyValues::F32(v) => v.iter().for_each(|x| x.write_le(out)),
            AnyValues::F64(v) => v.iter().for_each(|x| x.write_le(out)),
        }
    }

    /// Invert [`Self::write`], returning the values and the new cursor.
    pub fn read(buf: &[u8], pos: usize) -> Result<(AnyValues, usize)> {
        ensure!(buf.len() >= pos + 12, "value array header truncated");
        let lane = Lane::from_tag(u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()))?;
        let n = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().unwrap()) as usize;
        let start = pos + 12;
        let nbytes = n
            .checked_mul(lane.bytes())
            .ok_or_else(|| anyhow::anyhow!("value array count overflow"))?;
        ensure!(buf.len() >= start + nbytes, "value array payload truncated");
        fn decode<V: VertexValue>(buf: &[u8], n: usize) -> Vec<V> {
            buf.chunks_exact(V::BYTES).take(n).map(V::read_le).collect()
        }
        let body = &buf[start..start + nbytes];
        let vals = match lane {
            Lane::U32 => AnyValues::U32(decode(body, n)),
            Lane::U64 => AnyValues::U64(decode(body, n)),
            Lane::F32 => AnyValues::F32(decode(body, n)),
            Lane::F64 => AnyValues::F64(decode(body, n)),
        };
        Ok((vals, start + nbytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_tags_roundtrip() {
        for lane in Lane::ALL {
            assert_eq!(Lane::from_tag(lane.tag()).unwrap(), lane);
            assert!(lane.bytes() == 4 || lane.bytes() == 8);
        }
        assert!(Lane::from_tag(99).is_err());
    }

    #[test]
    fn scalar_wire_roundtrip_all_lanes() {
        fn rt<V: VertexValue>(x: V) {
            let mut buf = Vec::new();
            x.write_le(&mut buf);
            assert_eq!(buf.len(), V::BYTES);
            assert_eq!(V::read_le(&buf), x);
        }
        rt(0xDEAD_BEEFu32);
        rt(0x0123_4567_89AB_CDEFu64);
        rt(-1.5f32);
        rt(std::f64::consts::PI);
    }

    #[test]
    fn monoid_identities() {
        assert_eq!(u32::vmax_value().vmin(7), 7);
        assert_eq!(u64::vmin_value().vmax(7), 7);
        assert_eq!(f32::vmax_value().vmin(7.0), 7.0);
        assert_eq!(f64::vmin_value().vmax(7.0), 7.0);
        assert_eq!(u32::vzero().vadd(3), 3);
    }

    #[test]
    fn changed_predicate_per_lane() {
        assert!(u32::changed(1, 2, 0.0));
        assert!(!u32::changed(2, 2, 0.0));
        assert!(!f32::changed(f32::INFINITY, f32::INFINITY, 0.0));
        assert!(f32::changed(1.0, 1.5, 0.0));
        assert!(!f32::changed(1.0, 1.5, 1.0));
        assert!(!f64::changed(f64::INFINITY, f64::INFINITY, 0.0));
    }

    #[test]
    fn anyvalues_wire_roundtrip_all_lanes() {
        let cases: Vec<AnyValues> = vec![
            AnyValues::U32(vec![0, 1, u32::MAX]),
            AnyValues::U64(vec![42, u64::MAX]),
            AnyValues::F32(vec![0.5, f32::INFINITY, -1.0]),
            AnyValues::F64(vec![]),
        ];
        for v in cases {
            let mut buf = Vec::new();
            v.write(&mut buf);
            let (back, pos) = AnyValues::read(&buf, 0).unwrap();
            assert_eq!(back, v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn anyvalues_rejects_truncation_and_bad_lane() {
        let mut buf = Vec::new();
        AnyValues::U64(vec![1, 2, 3]).write(&mut buf);
        assert!(AnyValues::read(&buf[..buf.len() - 1], 0).is_err());
        assert!(AnyValues::read(&buf[..4], 0).is_err());
        let mut bad = buf.clone();
        bad[0] = 99;
        assert!(AnyValues::read(&bad, 0).is_err());
    }
}
