//! Compressed Sparse Row adjacency — the shard payload format (§II-B).
//!
//! A [`Csr`] covers a contiguous vertex interval `[lo, hi)` and stores the
//! *incoming* adjacency of each vertex in that interval (GraphMP groups a
//! shard's edges by destination): `row_ptr[v-lo] .. row_ptr[v-lo+1]` indexes
//! into `col`, which holds source vertex ids.

use crate::graph::{Edge, VertexId, Weight};

/// CSR over the interval `[lo, hi)`. `col` holds source ids of in-edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub lo: VertexId,
    pub hi: VertexId,
    /// len = (hi - lo) + 1; row_ptr[0] == 0; row_ptr.last() == col.len().
    pub row_ptr: Vec<u32>,
    /// Source ids, grouped by destination, ascending destination.
    pub col: Vec<VertexId>,
    /// Per-edge weights, parallel to `col`.  Empty ⇒ unweighted (every
    /// `val(u,v) = 1`, the conference paper's graphs).
    pub wgt: Vec<Weight>,
}

impl Csr {
    /// Build from edges whose destinations all lie in `[lo, hi)`.
    /// Edges need not be sorted; counting sort by destination is used
    /// (O(|E| + |interval|)).  `weights` must be empty (unweighted) or
    /// parallel to `edges`; it is permuted alongside `col`.
    pub fn from_edges_weighted(
        lo: VertexId,
        hi: VertexId,
        edges: &[Edge],
        weights: &[Weight],
    ) -> Self {
        // hard assert (not debug): a short weights slice would otherwise
        // surface as an opaque out-of-bounds panic mid-permutation
        assert!(
            weights.is_empty() || weights.len() == edges.len(),
            "weights must be empty or parallel to edges ({} vs {})",
            weights.len(),
            edges.len()
        );
        let n = (hi - lo) as usize;
        let mut counts = vec![0u32; n + 1];
        for &(_, d) in edges {
            debug_assert!(d >= lo && d < hi, "edge dst {d} outside [{lo},{hi})");
            counts[(d - lo) as usize + 1] += 1;
        }
        for i in 1..=n {
            counts[i] += counts[i - 1];
        }
        let row_ptr = counts.clone();
        let mut cursor = row_ptr.clone();
        let mut col = vec![0 as VertexId; edges.len()];
        let mut wgt = if weights.is_empty() {
            Vec::new()
        } else {
            vec![0.0 as Weight; edges.len()]
        };
        for (k, &(s, d)) in edges.iter().enumerate() {
            let slot = &mut cursor[(d - lo) as usize];
            col[*slot as usize] = s;
            if !weights.is_empty() {
                wgt[*slot as usize] = weights[k];
            }
            *slot += 1;
        }
        Csr { lo, hi, row_ptr, col, wgt }
    }

    /// Unweighted construction (unit `val(u,v)`).
    pub fn from_edges(lo: VertexId, hi: VertexId, edges: &[Edge]) -> Self {
        Self::from_edges_weighted(lo, hi, edges, &[])
    }

    /// Number of vertices in the interval.
    pub fn num_vertices(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    pub fn num_edges(&self) -> usize {
        self.col.len()
    }

    /// Does this shard carry an explicit weight lane?
    pub fn is_weighted(&self) -> bool {
        !self.wgt.is_empty()
    }

    /// Weight of edge slot `k` (an index into `col`); 1 when unweighted.
    #[inline]
    pub fn weight(&self, k: usize) -> Weight {
        if self.wgt.is_empty() {
            1.0
        } else {
            self.wgt[k]
        }
    }

    /// Incoming adjacency list of global vertex `v` (must be in interval).
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        debug_assert!(v >= self.lo && v < self.hi);
        let i = (v - self.lo) as usize;
        &self.col[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Weights of `v`'s in-edges, parallel to [`Self::in_neighbors`];
    /// empty when the shard is unweighted.
    pub fn in_weights(&self, v: VertexId) -> &[Weight] {
        if self.wgt.is_empty() {
            return &[];
        }
        debug_assert!(v >= self.lo && v < self.hi);
        let i = (v - self.lo) as usize;
        &self.wgt[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Iterate `(global_dst, in_neighbors)` pairs.
    pub fn iter_rows(&self) -> impl Iterator<Item = (VertexId, &[VertexId])> + '_ {
        (0..self.num_vertices()).map(move |i| {
            let v = self.lo + i as VertexId;
            (v, &self.col[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize])
        })
    }

    /// Flatten back to an edge list (for tests / round-trips).
    pub fn to_edges(&self) -> Vec<Edge> {
        self.iter_rows()
            .flat_map(|(v, srcs)| srcs.iter().map(move |&s| (s, v)))
            .collect()
    }

    /// Flatten to `(src, dst, weight)` triples (unit weights when
    /// unweighted) — for tests / round-trips.
    pub fn to_wedges(&self) -> Vec<(VertexId, VertexId, Weight)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for i in 0..self.num_vertices() {
            let v = self.lo + i as VertexId;
            for k in self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize {
                out.push((self.col[k], v, self.weight(k)));
            }
        }
        out
    }

    /// Structural validation (used after deserialization).
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.num_vertices();
        anyhow::ensure!(self.row_ptr.len() == n + 1, "row_ptr length");
        anyhow::ensure!(self.row_ptr[0] == 0, "row_ptr[0] != 0");
        anyhow::ensure!(
            *self.row_ptr.last().unwrap() as usize == self.col.len(),
            "row_ptr tail != col len"
        );
        anyhow::ensure!(
            self.row_ptr.windows(2).all(|w| w[0] <= w[1]),
            "row_ptr not monotone"
        );
        anyhow::ensure!(
            self.wgt.is_empty() || self.wgt.len() == self.col.len(),
            "weight lane length != col length"
        );
        Ok(())
    }
}

/// Whole-graph CSR over *out*-edges (used by the in-memory baseline and the
/// generators' degree pass). `row_ptr[v]..row_ptr[v+1]` → destinations of v.
#[derive(Debug, Clone)]
pub struct OutCsr {
    pub num_vertices: usize,
    pub row_ptr: Vec<u64>,
    pub col: Vec<VertexId>,
}

impl OutCsr {
    pub fn from_edges(num_vertices: usize, edges: &[Edge]) -> Self {
        let mut counts = vec![0u64; num_vertices + 1];
        for &(s, _) in edges {
            counts[s as usize + 1] += 1;
        }
        for i in 1..=num_vertices {
            counts[i] += counts[i - 1];
        }
        let row_ptr = counts.clone();
        let mut cursor = counts;
        let mut col = vec![0 as VertexId; edges.len()];
        for &(s, d) in edges {
            let slot = &mut cursor[s as usize];
            col[*slot as usize] = d;
            *slot += 1;
        }
        OutCsr { num_vertices, row_ptr, col }
    }

    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.col[self.row_ptr[v as usize] as usize..self.row_ptr[v as usize + 1] as usize]
    }

    pub fn num_edges(&self) -> usize {
        self.col.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn csr_roundtrip_small() {
        // interval [2,5): edges into 2,3,4
        let edges = vec![(0, 2), (1, 2), (7, 4), (3, 3), (2, 2)];
        let csr = Csr::from_edges(2, 5, &edges);
        csr.validate().unwrap();
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.num_edges(), 5);
        assert_eq!(csr.in_neighbors(2), &[0, 1, 2]);
        assert_eq!(csr.in_neighbors(3), &[3]);
        assert_eq!(csr.in_neighbors(4), &[7]);
        let mut back = csr.to_edges();
        back.sort_unstable();
        let mut want = edges.clone();
        want.sort_unstable();
        assert_eq!(back, want);
    }

    #[test]
    fn csr_empty_interval_rows() {
        let csr = Csr::from_edges(0, 4, &[]);
        csr.validate().unwrap();
        assert_eq!(csr.num_edges(), 0);
        assert_eq!(csr.in_neighbors(1), &[] as &[VertexId]);
    }

    #[test]
    fn prop_csr_preserves_multiset_of_edges() {
        prop::check(0xC5A, 50, |g| {
            let n = g.usize_in(1, 64) as u32;
            let m = g.usize_in(0, 256);
            let edges: Vec<Edge> = (0..m)
                .map(|_| (g.usize_in(0, 64) as u32, g.usize_in(0, n as usize) as u32))
                .collect();
            let csr = Csr::from_edges(0, n, &edges);
            csr.validate().unwrap();
            let mut back = csr.to_edges();
            back.sort_unstable();
            let mut want = edges;
            want.sort_unstable();
            assert_eq!(back, want);
        });
    }

    #[test]
    fn weighted_csr_permutes_weights_with_sources() {
        // interval [0,3): weights must follow their edges through the
        // counting sort
        let edges = vec![(5, 2), (1, 0), (9, 2), (4, 1), (2, 0)];
        let weights = vec![0.5, 1.5, 2.5, 3.5, 4.5];
        let csr = Csr::from_edges_weighted(0, 3, &edges, &weights);
        csr.validate().unwrap();
        assert!(csr.is_weighted());
        let mut triples = csr.to_wedges();
        triples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut want: Vec<(u32, u32, f32)> = edges
            .iter()
            .zip(&weights)
            .map(|(&(s, d), &w)| (s, d, w))
            .collect();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(triples, want);
        // per-row weight slices stay parallel to in_neighbors
        for v in 0..3u32 {
            assert_eq!(csr.in_neighbors(v).len(), csr.in_weights(v).len());
        }
        // unweighted shards report unit weights
        let u = Csr::from_edges(0, 3, &edges);
        assert!(!u.is_weighted());
        assert_eq!(u.weight(0), 1.0);
        assert!(u.in_weights(1).is_empty());
    }

    #[test]
    fn out_csr_neighbors() {
        let edges = vec![(0, 1), (0, 2), (2, 0)];
        let csr = OutCsr::from_edges(3, &edges);
        assert_eq!(csr.out_neighbors(0), &[1, 2]);
        assert_eq!(csr.out_neighbors(1), &[] as &[VertexId]);
        assert_eq!(csr.out_neighbors(2), &[0]);
    }
}
