//! Graph mutations: batched edge insertions/deletions, epoch ingestion and
//! compaction — the dynamic-graph layer over GraphMP's static shards.
//!
//! ## Semantics
//!
//! A batch is an **ordered** list of [`Mutation`]s applied to the current
//! epoch's edge multiset:
//!
//! * `Insert (s, d, w)` appends one new edge (the graph is a multigraph, so
//!   duplicates are legal);
//! * `Delete (s, d)` removes **every** live `(s, d)` edge — base edges via
//!   a tombstone in the shard's delta, previously inserted edges by
//!   pruning the delta's insert list.  Deleting an absent edge is a no-op.
//!
//! [`apply_batch`] is the executable specification on a plain edge list;
//! [`ingest`] implements the same semantics against a dataset directory by
//! bucketing mutations into per-interval delta shards
//! ([`crate::storage::delta::DeltaShard`]) and appending an epoch to the
//! snapshot manifest ([`crate::runtime::EpochManifest`]).  The equivalence
//! — delta-merged execution ≡ preprocessing the final edge list from
//! scratch, bit-for-bit — is the subsystem's acceptance bar
//! (`tests/delta_epochs.rs`), and it holds because both sides produce the
//! same per-row edge order: base survivors in base order, then inserts in
//! insertion order (stable counting sort on one side, ordered merge on the
//! other).
//!
//! ## Incremental restart
//!
//! For monotone programs (Min/Max reduce whose `apply` folds the old
//! value — the same property GridGraph-style row skipping relies on), an
//! **insert-only** mutation history lets a run warm-start from the previous
//! epoch's fixpoint: the old fixpoint is a valid over-approximation of the
//! new one, and seeding the active set with the sources of the inserted
//! edges triggers exactly the relaxations the new edges enable.
//! Deletions can *raise* Min-lattice values, which monotone re-iteration
//! cannot do on its own — so a delete-bearing history additionally resets
//! the forward closure of the deleted edges' destinations back to `init`
//! and re-derives them ([`incremental_plan`] / [`SeedPlan`]).  Sum lanes
//! recompute cold, except single-pass Sum programs, which the engine
//! maintains row-incrementally (`VswEngine::run_any_rows`).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::bloom::BloomFilter;
use crate::graph::csr::Csr;
use crate::graph::{Edge, VertexId, Weight};
use crate::runtime::{rel_name, Epoch, EpochManifest, EpochShard};
use crate::sharding::preprocess::{BLOOM_MAGIC, BLOOM_VERSION};
use crate::storage::delta::{self, DeltaShard};
use crate::storage::format::frame;
use crate::storage::property::Property;
use crate::storage::vertexinfo::VertexInfo;
use crate::storage::{durable, io, shardfile, DatasetDir};
use crate::util::rng::Xoshiro256;

/// One edge mutation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mutation {
    Insert { src: VertexId, dst: VertexId, weight: Weight },
    Delete { src: VertexId, dst: VertexId },
}

impl Mutation {
    pub fn src(&self) -> VertexId {
        match *self {
            Mutation::Insert { src, .. } | Mutation::Delete { src, .. } => src,
        }
    }

    pub fn dst(&self) -> VertexId {
        match *self {
            Mutation::Insert { dst, .. } | Mutation::Delete { dst, .. } => dst,
        }
    }

    pub fn is_insert(&self) -> bool {
        matches!(self, Mutation::Insert { .. })
    }
}

/// Apply one batch to a plain edge list — the executable specification
/// [`ingest`] is tested against.  `weights` must be empty (unweighted) or
/// parallel to `edges`; a non-unit insert weight promotes an unweighted
/// list to a weighted one (existing edges get weight 1).
pub fn apply_batch(
    edges: &mut Vec<Edge>,
    weights: &mut Vec<Weight>,
    batch: &[Mutation],
) -> Result<()> {
    anyhow::ensure!(
        weights.is_empty() || weights.len() == edges.len(),
        "weights must be empty or parallel to edges"
    );
    for m in batch {
        match *m {
            Mutation::Insert { src, dst, weight } => {
                // a non-unit weight promotes the list to weighted (prior
                // edges get unit weights); the flag also covers promotion
                // while the list is still empty
                let promote = weights.is_empty() && weight != 1.0;
                if promote {
                    weights.resize(edges.len(), 1.0);
                }
                edges.push((src, dst));
                if promote || !weights.is_empty() {
                    weights.push(weight);
                }
            }
            Mutation::Delete { src, dst } => {
                if weights.is_empty() {
                    edges.retain(|&e| e != (src, dst));
                } else {
                    // filter both parallel lanes in one ordered pass
                    let mut new_e = Vec::with_capacity(edges.len());
                    let mut new_w = Vec::with_capacity(weights.len());
                    for (k, &e) in edges.iter().enumerate() {
                        if e != (src, dst) {
                            new_e.push(e);
                            new_w.push(weights[k]);
                        }
                    }
                    *edges = new_e;
                    *weights = new_w;
                }
            }
        }
    }
    Ok(())
}

/// Apply a sequence of batches (convenience over [`apply_batch`]).
pub fn apply_batches(
    edges: &mut Vec<Edge>,
    weights: &mut Vec<Weight>,
    batches: &[Vec<Mutation>],
) -> Result<()> {
    for b in batches {
        apply_batch(edges, weights, b)?;
    }
    Ok(())
}

/// Summary returned by [`ingest`].
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// The newly created epoch id.
    pub epoch: u64,
    pub inserts: u64,
    pub deletes: u64,
    /// Live edges removed by the batch's deletes (base + prior inserts).
    pub edges_removed: u64,
    pub touched_shards: Vec<usize>,
    /// Live edges at the new epoch.
    pub num_edges: u64,
}

/// Apply one mutation batch to a preprocessed dataset: bucket mutations
/// into per-interval delta shards, rebuild Bloom filters for touched
/// shards, update the degree arrays, archive the batch, and append a new
/// epoch to the snapshot manifest.  Base shard files are never modified —
/// readers at older epochs keep reproducing their results.
pub fn ingest(dir: &DatasetDir, batch: &[Mutation], bloom_fpr: f64) -> Result<IngestReport> {
    anyhow::ensure!(!batch.is_empty(), "empty mutation batch");
    let property = Property::load(&dir.property_path()).context("property")?;
    let n = property.info.num_vertices;
    for (k, m) in batch.iter().enumerate() {
        anyhow::ensure!(
            (m.src() as u64) < n && (m.dst() as u64) < n,
            "mutation {k}: edge ({}, {}) outside vertex range {n} (the vertex universe is \
             fixed at preprocessing time)",
            m.src(),
            m.dst()
        );
        if let Mutation::Insert { weight, .. } = m {
            anyhow::ensure!(weight.is_finite(), "mutation {k}: non-finite weight");
        }
    }

    let mut manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
    let cur = manifest.latest().clone();
    let new_id = cur.id + 1;

    // bucket by destination interval, preserving batch order within each
    let mut per_shard: BTreeMap<usize, Vec<Mutation>> = BTreeMap::new();
    for &m in batch {
        per_shard.entry(property.shard_of(m.dst())).or_default().push(m);
    }

    let mut shards = cur.shards.clone();
    let mut out_deg_delta = vec![0i64; n as usize];
    let mut in_deg_delta = vec![0i64; n as usize];
    let (mut inserts, mut deletes, mut edges_removed) = (0u64, 0u64, 0u64);
    let mut touched = Vec::with_capacity(per_shard.len());
    // every artifact the new epoch will reference, fsynced before the
    // manifest publishes the reference (durability ordering: a crash after
    // manifest.save must find the files it names complete on disk)
    let mut new_artifacts: Vec<std::path::PathBuf> = Vec::new();

    for (&i, muts) in &per_shard {
        let (lo, hi) = property.interval(i);
        let base = shardfile::load(&dir.root.join(&cur.shards[i].shard))
            .with_context(|| format!("shard {i}"))?;
        anyhow::ensure!(
            (base.lo, base.hi) == (lo, hi),
            "shard {i} interval disagrees with property"
        );
        let rows = (hi - lo) as usize;
        // unpack the previous cumulative delta into per-row working lists
        let (mut ins_rows, mut tomb_rows, mut dropped) = match &cur.shards[i].delta {
            Some(f) => {
                let d = DeltaShard::load(&dir.root.join(f))
                    .with_context(|| format!("delta shard {i}"))?;
                anyhow::ensure!((d.lo, d.hi) == (lo, hi), "delta shard {i} interval");
                let mut ins: Vec<Vec<(VertexId, Weight)>> = vec![Vec::new(); rows];
                let mut tomb: Vec<Vec<VertexId>> = vec![Vec::new(); rows];
                for r in 0..rows {
                    let (s, e) = (d.ins_row_ptr[r] as usize, d.ins_row_ptr[r + 1] as usize);
                    for k in s..e {
                        ins[r].push((d.ins_col[k], d.ins_weight(k)));
                    }
                    tomb[r].extend_from_slice(d.row_tombs(r));
                }
                (ins, tomb, d.dropped_base)
            }
            None => (vec![Vec::new(); rows], vec![Vec::new(); rows], 0u64),
        };

        for &m in muts {
            match m {
                Mutation::Insert { src, dst, weight } => {
                    ins_rows[(dst - lo) as usize].push((src, weight));
                    out_deg_delta[src as usize] += 1;
                    in_deg_delta[dst as usize] += 1;
                    inserts += 1;
                }
                Mutation::Delete { src, dst } => {
                    deletes += 1;
                    let r = (dst - lo) as usize;
                    let before = ins_rows[r].len();
                    ins_rows[r].retain(|&(s, _)| s != src);
                    let mut removed = (before - ins_rows[r].len()) as u64;
                    if !tomb_rows[r].contains(&src) {
                        // tombstones kill base edges; count them once, when
                        // the tombstone first lands
                        let k = base
                            .in_neighbors(dst)
                            .iter()
                            .filter(|&&u| u == src)
                            .count() as u64;
                        if k > 0 {
                            tomb_rows[r].push(src);
                            dropped += k;
                            removed += k;
                        }
                    }
                    edges_removed += removed;
                    out_deg_delta[src as usize] -= removed as i64;
                    in_deg_delta[dst as usize] -= removed as i64;
                }
            }
        }

        let keep_weights = base.is_weighted()
            || ins_rows.iter().flatten().any(|&(_, w)| w != 1.0);
        let dshard = DeltaShard::from_rows(lo, hi, &ins_rows, &tomb_rows, dropped, keep_weights);
        if dshard.is_empty() {
            shards[i].delta = None;
        } else {
            let path = dir.delta_path(i, new_id);
            dshard.save(&path)?;
            shards[i].delta = Some(rel_name(&path));
            new_artifacts.push(path);
        }

        // Bloom rebuilt over the *merged* source set (no stale sources from
        // deleted edges, no false negatives for inserted ones)
        let merged_edges = dshard.effective_edges(base.num_edges() as u64) as usize;
        let mut bloom = BloomFilter::with_capacity(merged_edges.max(1), bloom_fpr);
        for r in 0..rows {
            let (s, e) = (base.row_ptr[r] as usize, base.row_ptr[r + 1] as usize);
            let tombs = dshard.row_tombs(r);
            for k in s..e {
                let u = base.col[k];
                if tombs.binary_search(&u).is_err() {
                    bloom.insert(u as u64);
                }
            }
            for &u in dshard.ins_sources(r) {
                bloom.insert(u as u64);
            }
        }
        let bpath = dir.epoch_bloom_path(i, new_id);
        io::write_file(&bpath, &frame(BLOOM_MAGIC, BLOOM_VERSION, &bloom.to_bytes()))?;
        shards[i].bloom = rel_name(&bpath);
        new_artifacts.push(bpath);
        touched.push(i);
    }

    // degree arrays follow the mutations; values lane is left empty
    let vi = VertexInfo::load(&dir.root.join(&cur.vertexinfo)).context("vertexinfo")?;
    let mut degrees = vi.degrees;
    for v in 0..n as usize {
        let new_out = degrees.out_deg[v] as i64 + out_deg_delta[v];
        let new_in = degrees.in_deg[v] as i64 + in_deg_delta[v];
        anyhow::ensure!(new_out >= 0 && new_in >= 0, "vertex {v}: degree underflow");
        degrees.out_deg[v] = new_out as u32;
        degrees.in_deg[v] = new_in as u32;
    }
    let vipath = dir.epoch_vertexinfo_path(new_id);
    VertexInfo::new(degrees).save(&vipath)?;
    new_artifacts.push(vipath.clone());

    let bpath = dir.batch_path(new_id);
    delta::save_log(batch, &bpath)?;
    new_artifacts.push(bpath.clone());

    for p in &new_artifacts {
        durable::sync_file(p)?;
    }

    let num_edges = cur.num_edges + inserts - edges_removed;
    manifest.epochs.push(Epoch {
        id: new_id,
        kind: "ingest".into(),
        parent: Some(cur.id),
        num_edges,
        vertexinfo: rel_name(&vipath),
        batch: Some(rel_name(&bpath)),
        inserts,
        deletes,
        shards,
    });
    manifest.current = new_id;
    manifest.save(dir)?;

    Ok(IngestReport {
        epoch: new_id,
        inserts,
        deletes,
        edges_removed,
        touched_shards: touched,
        num_edges,
    })
}

/// Summary returned by [`compact`].
#[derive(Debug, Clone)]
pub struct CompactReport {
    /// The new epoch id, or `None` when nothing crossed the threshold.
    pub epoch: Option<u64>,
    pub compacted_shards: Vec<usize>,
    /// Shards whose delta/base ratio stayed below the threshold.
    pub skipped_shards: usize,
}

/// Rewrite merged shard files for every shard whose delta/base edge ratio
/// reaches `min_ratio` (`0.0` compacts every delta-bearing shard).  The
/// merged file replays the exact row order the delta-merged stream
/// produced, so results are bit-identical before and after; old epochs
/// keep their files.  A no-op (nothing to compact) appends no epoch.
pub fn compact(dir: &DatasetDir, min_ratio: f64) -> Result<CompactReport> {
    let property = Property::load(&dir.property_path()).context("property")?;
    let mut manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
    let cur = manifest.latest().clone();
    let new_id = cur.id + 1;
    let mut shards = cur.shards.clone();
    let mut compacted = Vec::new();
    let mut skipped = 0usize;

    for i in 0..shards.len() {
        let Some(dname) = &cur.shards[i].delta else { continue };
        let dshard = DeltaShard::load(&dir.root.join(dname))
            .with_context(|| format!("delta shard {i}"))?;
        let base = shardfile::load(&dir.root.join(&cur.shards[i].shard))
            .with_context(|| format!("shard {i}"))?;
        let ratio = (dshard.ins_count() as f64 + dshard.dropped_base as f64)
            / base.num_edges().max(1) as f64;
        if ratio < min_ratio {
            skipped += 1;
            continue;
        }
        let merged = dshard.merge(&base);
        merged.validate().with_context(|| format!("merged shard {i}"))?;
        let path = dir.epoch_shard_path(i, new_id);
        shardfile::save(&merged, &path)?;
        durable::sync_file(&path)?;
        // edge set unchanged ⇒ the epoch's bloom stays valid; only the base
        // file (and its cache-invalidation epoch) moves
        shards[i] = EpochShard {
            shard: rel_name(&path),
            bloom: cur.shards[i].bloom.clone(),
            delta: None,
            shard_epoch: new_id,
        };
        compacted.push(i);
    }

    if compacted.is_empty() {
        return Ok(CompactReport { epoch: None, compacted_shards: vec![], skipped_shards: skipped });
    }
    manifest.epochs.push(Epoch {
        id: new_id,
        kind: "compact".into(),
        parent: Some(cur.id),
        num_edges: cur.num_edges,
        vertexinfo: cur.vertexinfo.clone(),
        batch: None,
        inserts: 0,
        deletes: 0,
        shards,
    });
    manifest.current = new_id;
    manifest.save(dir)?;
    Ok(CompactReport {
        epoch: Some(new_id),
        compacted_shards: compacted,
        skipped_shards: skipped,
    })
}

/// What a monotone (Min/Max) warm restart from epoch `from` to `to` must
/// do before re-iterating: reset `reset` back to `init` (their old values
/// may no longer be derivable once edges were deleted), then re-converge
/// with `seed` as the active set.  Insert-only history yields an empty
/// `reset` — the classic seeded restart.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeedPlan {
    /// Vertices whose saved values a delete may have orphaned: the forward
    /// closure of the deleted edges' destinations (plus, conservatively,
    /// the out-neighbors of delete sources — degree-dependent gathers see
    /// their contribution change).  Empty for insert-only history.
    pub reset: Vec<VertexId>,
    /// Warm-restart active seed: inserted-edge sources, every reset vertex
    /// (its change to `init` must propagate), and the current in-edge
    /// sources of reset vertices (so their rows get recomputed).
    pub seed: Vec<VertexId>,
}

impl SeedPlan {
    pub fn has_resets(&self) -> bool {
        !self.reset.is_empty()
    }
}

/// Plan a monotone warm restart from epoch `from` to `to`.
///
/// Insert-only history: `seed` = deduplicated sources of inserted edges,
/// no resets (the old fixpoint over-approximates the new one everywhere).
///
/// Delete-bearing history: a tombstone can orphan a saved value — the
/// derivation that produced it may have run through the deleted edge.  The
/// set of possibly-orphaned vertices is the *forward closure* `F` of the
/// deleted edges' destinations over the old edge set (⊆ current ∪ deleted):
/// any vertex with an in-edge from `F` could have derived its value from an
/// `F` vertex and joins `F`.  Resetting `F` to `init` and seeding
/// `inserted sources ∪ F ∪ in-sources(F)` restores the warm invariant: no
/// vertex outside `F` ever read a reset value, every reset vertex is
/// recomputed from live in-edges, and the reset itself propagates.
/// Degree-dependent gathers (`src_out_deg`) are covered by also closing
/// over the delete sources' current out-neighbors.
///
/// Returns `Ok(None)` — caller must cold-start — when history is
/// unreplayable: an epoch with no archived batch, an archived batch file
/// pruned from disk, or a delete-bearing plan whose `to` is not the
/// manifest's current epoch (the closure is computed against the current
/// edge set).  Corrupt batch files are still hard errors.
pub fn incremental_plan(
    dir: &DatasetDir,
    manifest: &EpochManifest,
    from: u64,
    to: u64,
) -> Result<Option<SeedPlan>> {
    let mut ins_src: Vec<VertexId> = Vec::new();
    let mut dels: Vec<Edge> = Vec::new();
    for e in manifest.epochs_between(from, to) {
        if e.kind == "compact" {
            continue; // no logical change
        }
        let Some(b) = &e.batch else {
            return Ok(None); // nothing to replay — degrade to cold
        };
        let path = dir.root.join(b);
        if !path.exists() {
            return Ok(None); // archived batch pruned — degrade to cold
        }
        for m in delta::load_log(&path)? {
            match m {
                Mutation::Insert { src, .. } => ins_src.push(src),
                Mutation::Delete { src, dst } => dels.push((src, dst)),
            }
        }
    }
    ins_src.sort_unstable();
    ins_src.dedup();
    if dels.is_empty() {
        return Ok(Some(SeedPlan { reset: Vec::new(), seed: ins_src }));
    }
    // the closure below reads the *current* edge set; a historical target
    // epoch would need the edge set as of `to`, which we don't reconstruct
    if to != manifest.current {
        return Ok(None);
    }
    let property = Property::load(&dir.property_path())?;
    let n = property.info.num_vertices as usize;
    let (edges, _weights) = current_edges(dir)?;

    // initial frontier: deleted destinations, plus current out-neighbors
    // of delete sources (their out-degree changed — a degree-dependent
    // gather's contribution along every surviving out-edge changed too)
    let mut del_src = vec![false; n];
    let mut in_frontier = vec![false; n];
    for &(s, d) in &dels {
        del_src[s as usize] = true;
        in_frontier[d as usize] = true;
    }
    // forward closure over old edges ⊆ current ∪ deleted, following src→dst
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); n];
    for &(s, d) in edges.iter().chain(dels.iter()) {
        adj[s as usize].push(d);
        if del_src[s as usize] {
            in_frontier[d as usize] = true;
        }
    }
    let mut stack: Vec<VertexId> =
        (0..n as VertexId).filter(|&v| in_frontier[v as usize]).collect();
    while let Some(v) = stack.pop() {
        for &w in &adj[v as usize] {
            if !in_frontier[w as usize] {
                in_frontier[w as usize] = true;
                stack.push(w);
            }
        }
    }
    let reset: Vec<VertexId> =
        (0..n as VertexId).filter(|&v| in_frontier[v as usize]).collect();

    // seed: insert sources, the reset set itself, and every current
    // in-source of a reset vertex (forces its row to be recomputed)
    let mut seed = ins_src;
    seed.extend_from_slice(&reset);
    for &(s, d) in &edges {
        if in_frontier[d as usize] {
            seed.push(s);
        }
    }
    seed.sort_unstable();
    seed.dedup();
    Ok(Some(SeedPlan { reset, seed }))
}

/// The current epoch's full edge list (merged base + deltas), shard by
/// shard.  `weights` is empty when no shard carries a weight lane.  Used by
/// `graphmp mutate-gen` to aim deletes at live edges and by tests as a
/// convenient merged view; the order is per-shard row order, not the
/// original input order.
pub fn current_edges(dir: &DatasetDir) -> Result<(Vec<Edge>, Vec<Weight>)> {
    let property = Property::load(&dir.property_path())?;
    let manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
    let cur = manifest.latest();
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    let mut any_weighted = false;
    for (i, s) in cur.shards.iter().enumerate() {
        let base = shardfile::load(&dir.root.join(&s.shard))
            .with_context(|| format!("shard {i}"))?;
        let csr = match &s.delta {
            Some(f) => DeltaShard::load(&dir.root.join(f))?.merge(&base),
            None => base,
        };
        if csr.is_weighted() {
            if !any_weighted {
                weights.resize(edges.len(), 1.0);
                any_weighted = true;
            }
            for (s, d, w) in csr.to_wedges() {
                edges.push((s, d));
                weights.push(w);
            }
        } else {
            for e in csr.to_edges() {
                edges.push(e);
                if any_weighted {
                    weights.push(1.0);
                }
            }
        }
    }
    Ok((edges, weights))
}

/// Deterministic synthetic mutation batch against a live edge set: inserts
/// random edges (weighted when `weighted`), deletes aim at currently live
/// edges (existing ∪ batch inserts so far) so tombstones actually fire.
/// Pure function of its arguments — benches and CI smoke legs get
/// reproducible workloads.
pub fn synth_batch(
    num_vertices: usize,
    existing: &[Edge],
    count: usize,
    delete_fraction: f64,
    weighted: bool,
    seed: u64,
) -> Vec<Mutation> {
    assert!(num_vertices > 0, "need at least one vertex");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut live: Vec<Edge> = existing.to_vec();
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if !live.is_empty() && rng.chance(delete_fraction) {
            let k = rng.range_usize(0, live.len());
            let (src, dst) = live[k];
            // a delete kills every (src, dst) occurrence
            live.retain(|&e| e != (src, dst));
            out.push(Mutation::Delete { src, dst });
        } else {
            let src = rng.range_usize(0, num_vertices) as VertexId;
            let dst = rng.range_usize(0, num_vertices) as VertexId;
            let weight = if weighted {
                (rng.range_usize(1, 9) as Weight) * 0.25
            } else {
                1.0
            };
            live.push((src, dst));
            out.push(Mutation::Insert { src, dst, weight });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sharding::{preprocess, preprocess_weighted, PreprocessConfig};

    fn tmpdir(tag: &str) -> DatasetDir {
        let d = std::env::temp_dir().join(format!("gmp_mut_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        DatasetDir::new(d)
    }

    #[test]
    fn apply_batch_semantics() {
        // delete kills all occurrences incl. prior inserts; reinsert lives
        let mut edges = vec![(0u32, 1u32), (2, 1), (0, 1)];
        let mut weights = Vec::new();
        apply_batch(
            &mut edges,
            &mut weights,
            &[
                Mutation::Insert { src: 0, dst: 1, weight: 1.0 },
                Mutation::Delete { src: 0, dst: 1 },
                Mutation::Insert { src: 0, dst: 1, weight: 1.0 },
            ],
        )
        .unwrap();
        assert_eq!(edges, vec![(2, 1), (0, 1)]);
        assert!(weights.is_empty(), "unit weights stay implicit");

        // a non-unit insert weight promotes the list to weighted
        apply_batch(
            &mut edges,
            &mut weights,
            &[Mutation::Insert { src: 3, dst: 0, weight: 2.5 }],
        )
        .unwrap();
        assert_eq!(edges, vec![(2, 1), (0, 1), (3, 0)]);
        assert_eq!(weights, vec![1.0, 1.0, 2.5]);

        // weighted delete keeps the lanes parallel
        apply_batch(&mut edges, &mut weights, &[Mutation::Delete { src: 0, dst: 1 }]).unwrap();
        assert_eq!(edges, vec![(2, 1), (3, 0)]);
        assert_eq!(weights, vec![1.0, 2.5]);
    }

    #[test]
    fn ingest_creates_epoch_and_updates_degrees() {
        let edges = vec![(0u32, 1u32), (1, 2), (2, 0), (0, 2), (3, 1)];
        let dir = tmpdir("ing");
        let cfg = PreprocessConfig { max_edges_per_shard: 2, bloom_fpr: 0.01 };
        preprocess("m", &edges, 4, &dir, &cfg).unwrap();
        let report = ingest(
            &dir,
            &[
                Mutation::Insert { src: 3, dst: 0, weight: 1.0 },
                Mutation::Delete { src: 1, dst: 2 },
            ],
            0.01,
        )
        .unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(report.inserts, 1);
        assert_eq!(report.edges_removed, 1);
        assert_eq!(report.num_edges, 5);
        let property = Property::load(&dir.property_path()).unwrap();
        let manifest = EpochManifest::load(&dir.epochs_path()).unwrap();
        assert_eq!(manifest.current, 1);
        let e = manifest.latest();
        assert_eq!(e.kind, "ingest");
        assert!(e.batch.is_some());
        // degrees moved with the mutations
        let vi = VertexInfo::load(&dir.root.join(&e.vertexinfo)).unwrap();
        assert_eq!(vi.degrees.out_deg[3], 2, "insert raised out-degree");
        assert_eq!(vi.degrees.out_deg[1], 0, "delete lowered out-degree");
        assert_eq!(vi.degrees.in_deg[0], 2);
        // merged view equals the specification applied to the input list
        let (mut got, _) = current_edges(&dir).unwrap();
        got.sort_unstable();
        let mut want = edges.clone();
        let mut w = Vec::new();
        apply_batch(
            &mut want,
            &mut w,
            &[
                Mutation::Insert { src: 3, dst: 0, weight: 1.0 },
                Mutation::Delete { src: 1, dst: 2 },
            ],
        )
        .unwrap();
        want.sort_unstable();
        assert_eq!(got, want);
        let _ = property;
    }

    #[test]
    fn ingest_rejects_out_of_range_and_empty() {
        let dir = tmpdir("rej");
        preprocess("m", &[(0, 1)], 2, &dir, &PreprocessConfig::default()).unwrap();
        assert!(ingest(&dir, &[], 0.01).is_err());
        assert!(
            ingest(&dir, &[Mutation::Insert { src: 0, dst: 9, weight: 1.0 }], 0.01).is_err()
        );
        assert!(ingest(
            &dir,
            &[Mutation::Insert { src: 0, dst: 1, weight: f32::NAN }],
            0.01
        )
        .is_err());
    }

    #[test]
    fn compact_merges_and_respects_threshold() {
        let edges = generator::erdos_renyi(64, 400, 5);
        let weights = generator::synth_weights(&edges, 3);
        let dir = tmpdir("cmp");
        let cfg = PreprocessConfig { max_edges_per_shard: 64, bloom_fpr: 0.01 };
        preprocess_weighted("m", &edges, &weights, 64, &dir, &cfg).unwrap();
        // heavy mutations on shard of vertex 0, nothing elsewhere
        let batch = vec![
            Mutation::Insert { src: 5, dst: 0, weight: 0.5 },
            Mutation::Insert { src: 6, dst: 0, weight: 0.75 },
            Mutation::Insert { src: 7, dst: 1, weight: 0.25 },
        ];
        ingest(&dir, &batch, 0.01).unwrap();
        let (edges_before, weights_before) = current_edges(&dir).unwrap();
        // a sky-high threshold compacts nothing and appends no epoch
        let r = compact(&dir, 1e9).unwrap();
        assert!(r.epoch.is_none());
        assert!(r.compacted_shards.is_empty());
        assert!(r.skipped_shards > 0);
        // threshold 0 compacts every delta-bearing shard
        let r = compact(&dir, 0.0).unwrap();
        assert_eq!(r.epoch, Some(2));
        assert!(!r.compacted_shards.is_empty());
        let manifest = EpochManifest::load(&dir.epochs_path()).unwrap();
        let e = manifest.latest();
        assert_eq!(e.kind, "compact");
        for &i in &r.compacted_shards {
            assert_eq!(e.shards[i].shard_epoch, 2, "compaction must bump the file epoch");
            assert!(e.shards[i].delta.is_none());
        }
        // the merged view is unchanged by compaction
        let (edges_after, weights_after) = current_edges(&dir).unwrap();
        let key = |e: &[(u32, u32)], w: &[f32]| {
            let mut v: Vec<(u32, u32, u32)> = e
                .iter()
                .enumerate()
                .map(|(k, &(s, d))| (s, d, if w.is_empty() { 0 } else { w[k].to_bits() }))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&edges_before, &weights_before), key(&edges_after, &weights_after));
    }

    #[test]
    fn incremental_plan_collects_insert_sources_and_derives_delete_resets() {
        let dir = tmpdir("seed");
        preprocess("m", &[(0, 1), (1, 2)], 8, &dir, &PreprocessConfig::default()).unwrap();
        ingest(&dir, &[Mutation::Insert { src: 4, dst: 2, weight: 1.0 }], 0.01).unwrap();
        ingest(
            &dir,
            &[
                Mutation::Insert { src: 5, dst: 3, weight: 1.0 },
                Mutation::Insert { src: 4, dst: 1, weight: 1.0 },
            ],
            0.01,
        )
        .unwrap();
        let property = Property::load(&dir.property_path()).unwrap();
        let manifest = EpochManifest::load_or_bootstrap(&dir, &property).unwrap();
        assert_eq!(
            incremental_plan(&dir, &manifest, 0, 2).unwrap(),
            Some(SeedPlan { reset: vec![], seed: vec![4, 5] })
        );
        assert_eq!(
            incremental_plan(&dir, &manifest, 1, 2).unwrap(),
            Some(SeedPlan { reset: vec![], seed: vec![4, 5] })
        );
        assert_eq!(
            incremental_plan(&dir, &manifest, 2, 2).unwrap(),
            Some(SeedPlan { reset: vec![], seed: vec![] }),
            "no epochs in range, empty plan"
        );
        // current edges: 0→1, 1→2, 4→2, 5→3, 4→1; delete 0→1.
        // Forward closure of dst 1 over old edges: {1, 2}; 0's surviving
        // out-neighbors: none left.  Resets {1, 2}; seed adds their current
        // in-sources {1, 4} and the reset set itself.
        ingest(&dir, &[Mutation::Delete { src: 0, dst: 1 }], 0.01).unwrap();
        let manifest = EpochManifest::load(&dir.epochs_path()).unwrap();
        let plan = incremental_plan(&dir, &manifest, 2, 3).unwrap().expect("delete plan");
        assert_eq!(plan.reset, vec![1, 2]);
        assert!(plan.has_resets());
        assert_eq!(plan.seed, vec![1, 2, 4], "reset set ∪ in-sources of resets");
        let full = incremental_plan(&dir, &manifest, 0, 3).unwrap().expect("full-range plan");
        assert_eq!(full.reset, vec![1, 2]);
        assert_eq!(full.seed, vec![1, 2, 4, 5], "insert sources join the seed");
        // a delete-bearing plan against a non-current target degrades cold
        ingest(&dir, &[Mutation::Insert { src: 6, dst: 7, weight: 1.0 }], 0.01).unwrap();
        let manifest = EpochManifest::load(&dir.epochs_path()).unwrap();
        assert_eq!(incremental_plan(&dir, &manifest, 0, 3).unwrap(), None);
        // a pruned archived batch degrades cold instead of erroring
        std::fs::remove_file(dir.batch_path(4)).unwrap();
        assert_eq!(incremental_plan(&dir, &manifest, 3, 4).unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn synth_batch_is_deterministic_and_deletes_hit_live_edges() {
        let existing = vec![(0u32, 1u32), (2, 3)];
        let a = synth_batch(16, &existing, 40, 0.3, true, 7);
        let b = synth_batch(16, &existing, 40, 0.3, true, 7);
        assert_eq!(a, b);
        assert!(a.iter().any(|m| m.is_insert()));
        assert!(a.iter().any(|m| !m.is_insert()), "0.3 delete fraction over 40 draws");
        // replay deletes against the live set: every delete must hit
        let mut live = existing.clone();
        for m in &a {
            match *m {
                Mutation::Insert { src, dst, .. } => live.push((src, dst)),
                Mutation::Delete { src, dst } => {
                    let before = live.len();
                    live.retain(|&e| e != (src, dst));
                    assert!(live.len() < before, "delete aimed at a dead edge");
                }
            }
        }
    }
}
