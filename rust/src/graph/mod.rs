//! Graph substrate: vertex/edge types, edge-list I/O, CSR construction and
//! synthetic graph generators.
//!
//! The paper evaluates on power-law webgraphs (Twitter, UK-2007, UK-2014,
//! EU-2015).  Those are proprietary-scale downloads, so [`generator`]
//! produces R-MAT graphs with matching average degree and skew at ~1000×
//! reduced scale (see DESIGN.md §3).

pub mod csr;
pub mod edgelist;
pub mod generator;
pub mod mutation;
pub mod value;

pub use value::{AnyValues, Lane, VertexValue};

/// Vertex identifier. 32 bits covers the scaled datasets (≤ a few million
/// vertices) and matches the paper's CSR `col` array element size (D=4..8B).
pub type VertexId = u32;

/// A directed edge `(src, dst)`. The conference paper's graphs are
/// unweighted (§II-A: `val(u,v) = 1`); the optional per-edge weight lane
/// ([`Weight`]) carries `val(u,v)` when a workload needs it.
pub type Edge = (VertexId, VertexId);

/// Per-edge weight lane. `f32` everywhere: it is `val(u,v)` in the paper's
/// notation, and programs on wider lanes lift it via
/// [`VertexValue::from_weight`].  An empty weight array means "unit weights"
/// (every `val(u,v) = 1`), which reproduces the unweighted semantics
/// bit-for-bit.
pub type Weight = f32;

/// Basic graph statistics gathered by the preprocessing scan (step 1 of
/// §II-B) and stored in the property file.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphInfo {
    pub num_vertices: u64,
    pub num_edges: u64,
    pub max_in_degree: u32,
    pub max_out_degree: u32,
}

impl GraphInfo {
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices == 0 {
            0.0
        } else {
            self.num_edges as f64 / self.num_vertices as f64
        }
    }
}

/// In/out degree arrays (the paper's vertex information file).
#[derive(Debug, Clone, Default)]
pub struct Degrees {
    pub in_deg: Vec<u32>,
    pub out_deg: Vec<u32>,
}

impl Degrees {
    /// Single pass over an edge iterator.
    pub fn from_edges<I: IntoIterator<Item = Edge>>(num_vertices: usize, edges: I) -> Self {
        let mut d = Degrees { in_deg: vec![0; num_vertices], out_deg: vec![0; num_vertices] };
        for (s, t) in edges {
            d.out_deg[s as usize] += 1;
            d.in_deg[t as usize] += 1;
        }
        d
    }

    pub fn info(&self, num_edges: u64) -> GraphInfo {
        GraphInfo {
            num_vertices: self.in_deg.len() as u64,
            num_edges,
            max_in_degree: self.in_deg.iter().copied().max().unwrap_or(0),
            max_out_degree: self.out_deg.iter().copied().max().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degrees_from_edges() {
        let edges = vec![(0, 1), (0, 2), (1, 2), (2, 0)];
        let d = Degrees::from_edges(3, edges.iter().copied());
        assert_eq!(d.out_deg, vec![2, 1, 1]);
        assert_eq!(d.in_deg, vec![1, 1, 2]);
        let info = d.info(4);
        assert_eq!(info.max_in_degree, 2);
        assert_eq!(info.max_out_degree, 2);
        assert!((info.avg_degree() - 4.0 / 3.0).abs() < 1e-12);
    }
}
