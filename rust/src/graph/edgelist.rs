//! Edge-list file formats.
//!
//! * **Text** — one `src dst [weight]` triple per line (whitespace
//!   separated, `#` comments), the lingua franca of SNAP/LAW downloads; the
//!   preprocessing pipeline ingests this.  The weight column is optional
//!   and must be present on every edge line or none.
//! * **Binary** — `GMEL` magic + u64 count + little-endian records + CRC32;
//!   compact interchange between the generator and the preprocessor.
//!   Version 1 records are `u32,u32` pairs (unweighted); version 2 records
//!   append an `f32` weight (`u32,u32,f32`).  Readers accept both.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::{Edge, Weight};

const BIN_MAGIC: &[u8; 4] = b"GMEL";
/// v1 = 8-byte (src,dst) records; v2 = 12-byte (src,dst,weight) records.
const BIN_VERSION_UNWEIGHTED: u32 = 1;
const BIN_VERSION_WEIGHTED: u32 = 2;

/// Write edges as text (`src<TAB>dst` per line).
pub fn write_text(path: &Path, edges: &[Edge]) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# graphmp edge list: src\tdst")?;
    for &(s, d) in edges {
        writeln!(w, "{s}\t{d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Write edges as weighted text (`src<TAB>dst<TAB>weight` per line);
/// `weights` must be parallel to `edges`.
pub fn write_text_weighted(path: &Path, edges: &[Edge], weights: &[Weight]) -> Result<()> {
    anyhow::ensure!(weights.len() == edges.len(), "weights must be parallel to edges");
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# graphmp edge list: src\tdst\tweight")?;
    for (&(s, d), &wt) in edges.iter().zip(weights) {
        writeln!(w, "{s}\t{d}\t{wt}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read a text edge list; tolerates comments and blank lines, ignores a
/// weight column if present.
pub fn read_text(path: &Path) -> Result<Vec<Edge>> {
    Ok(read_text_weighted(path)?.0)
}

/// Read a text edge list with its optional weight column.  Returns
/// `(edges, weights)`; `weights` is empty when no line carries a third
/// field.  Mixing weighted and unweighted lines is an error.
pub fn read_text_weighted(path: &Path) -> Result<(Vec<Edge>, Vec<Weight>)> {
    let r = BufReader::new(File::open(path).with_context(|| path.display().to_string())?);
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            bail!("line {}: expected two fields", lineno + 1);
        };
        let s: u32 = a.parse().with_context(|| format!("line {}: src", lineno + 1))?;
        let d: u32 = b.parse().with_context(|| format!("line {}: dst", lineno + 1))?;
        if let Some(c) = it.next() {
            let w: Weight =
                c.parse().with_context(|| format!("line {}: weight", lineno + 1))?;
            anyhow::ensure!(
                weights.len() == edges.len(),
                "line {}: weighted line in an unweighted list",
                lineno + 1
            );
            weights.push(w);
        } else {
            anyhow::ensure!(
                weights.is_empty(),
                "line {}: unweighted line in a weighted list",
                lineno + 1
            );
        }
        edges.push((s, d));
    }
    Ok((edges, weights))
}

fn write_binary_impl(path: &Path, edges: &[Edge], weights: &[Weight]) -> Result<()> {
    let weighted = !weights.is_empty();
    if weighted {
        anyhow::ensure!(weights.len() == edges.len(), "weights must be parallel to edges");
    }
    let version = if weighted { BIN_VERSION_WEIGHTED } else { BIN_VERSION_UNWEIGHTED };
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(edges.len() as u64).to_le_bytes())?;
    let mut crc = crc32fast::Hasher::new();
    // chunked buffer to keep syscalls and hasher updates amortized
    let mut buf = Vec::with_capacity(8 * 1024);
    for (k, &(s, d)) in edges.iter().enumerate() {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        if weighted {
            buf.extend_from_slice(&weights[k].to_le_bytes());
        }
        if buf.len() >= 8 * 1024 {
            crc.update(&buf);
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        crc.update(&buf);
        w.write_all(&buf)?;
    }
    w.write_all(&crc.finalize().to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Write the binary edge-list format (v1, unweighted).
pub fn write_binary(path: &Path, edges: &[Edge]) -> Result<()> {
    write_binary_impl(path, edges, &[])
}

/// Write the weighted binary edge-list format (v2).
pub fn write_binary_weighted(path: &Path, edges: &[Edge], weights: &[Weight]) -> Result<()> {
    anyhow::ensure!(!weights.is_empty(), "use write_binary for unweighted lists");
    write_binary_impl(path, edges, weights)
}

/// Read the binary edge-list format (either version), discarding weights.
pub fn read_binary(path: &Path) -> Result<Vec<Edge>> {
    Ok(read_binary_weighted(path)?.0)
}

/// Read the binary edge-list format, verifying magic/version/CRC.
/// Returns `(edges, weights)`; `weights` is empty for v1 files.
pub fn read_binary_weighted(path: &Path) -> Result<(Vec<Edge>, Vec<Weight>)> {
    let mut stream = BinaryEdgeStream::open(path)?;
    let weighted = stream.weighted();
    let n = stream.len_hint() as usize;
    let mut edges = Vec::with_capacity(n);
    let mut weights = Vec::with_capacity(if weighted { n } else { 0 });
    for item in &mut stream {
        let ((s, d), w) = item?;
        edges.push((s, d));
        if weighted {
            weights.push(w);
        }
    }
    Ok((edges, weights))
}

/// Streaming binary-edge-list reader: yields `(edge, weight)` items without
/// materializing the whole list (the external-memory preprocessing path).
/// v1 files yield unit weights.  CRC is verified incrementally; a corrupt
/// tail surfaces as an `Err` item.
pub struct BinaryEdgeStream {
    r: BufReader<File>,
    remaining: u64,
    weighted: bool,
    crc: crc32fast::Hasher,
    path: std::path::PathBuf,
}

impl BinaryEdgeStream {
    pub fn open(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(File::open(path).with_context(|| path.display().to_string())?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != BIN_MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        let weighted = match version {
            BIN_VERSION_UNWEIGHTED => false,
            BIN_VERSION_WEIGHTED => true,
            other => bail!("{}: unsupported version {other}", path.display()),
        };
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        Ok(Self {
            r,
            remaining: u64::from_le_bytes(b8),
            weighted,
            crc: crc32fast::Hasher::new(),
            path: path.to_path_buf(),
        })
    }

    /// Total edges declared by the header (remaining at open time).
    pub fn len_hint(&self) -> u64 {
        self.remaining
    }

    /// Does this file carry a weight lane (v2)?
    pub fn weighted(&self) -> bool {
        self.weighted
    }
}

impl Iterator for BinaryEdgeStream {
    type Item = Result<(Edge, Weight)>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            // verify trailing CRC once
            let mut b4 = [0u8; 4];
            if let Err(e) = self.r.read_exact(&mut b4) {
                return Some(Err(e.into()));
            }
            let want = u32::from_le_bytes(b4);
            let got = std::mem::replace(&mut self.crc, crc32fast::Hasher::new()).finalize();
            self.remaining = u64::MAX; // terminal state
            if got != want {
                return Some(Err(anyhow::anyhow!(
                    "{}: CRC mismatch (corrupt edge stream)",
                    self.path.display()
                )));
            }
            return None;
        }
        if self.remaining == u64::MAX {
            return None;
        }
        let mut buf = [0u8; 12];
        let rec = if self.weighted { 12 } else { 8 };
        match self.r.read_exact(&mut buf[..rec]) {
            Ok(()) => {
                self.crc.update(&buf[..rec]);
                self.remaining -= 1;
                let s = u32::from_le_bytes(buf[0..4].try_into().unwrap());
                let d = u32::from_le_bytes(buf[4..8].try_into().unwrap());
                let w = if self.weighted {
                    f32::from_le_bytes(buf[8..12].try_into().unwrap())
                } else {
                    1.0
                };
                Some(Ok(((s, d), w)))
            }
            Err(e) => {
                self.remaining = u64::MAX;
                Some(Err(e.into()))
            }
        }
    }
}

/// Auto-detect format by magic bytes, discarding any weight lane.
pub fn read_auto(path: &Path) -> Result<Vec<Edge>> {
    Ok(read_auto_weighted(path)?.0)
}

/// Auto-detect format by magic bytes, keeping the weight lane when the
/// file carries one (`weights` empty otherwise).
pub fn read_auto_weighted(path: &Path) -> Result<(Vec<Edge>, Vec<Weight>)> {
    let mut f = File::open(path).with_context(|| path.display().to_string())?;
    let mut magic = [0u8; 4];
    let got = f.read(&mut magic)?;
    drop(f);
    if got == 4 && &magic == BIN_MAGIC {
        read_binary_weighted(path)
    } else {
        read_text_weighted(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gmp_el_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn text_roundtrip() {
        let p = tmp("t.txt");
        let edges = vec![(0, 1), (42, 7), (7, 42)];
        write_text(&p, &edges).unwrap();
        assert_eq!(read_text(&p).unwrap(), edges);
        assert_eq!(read_auto(&p).unwrap(), edges);
    }

    #[test]
    fn weighted_text_roundtrip() {
        let p = tmp("tw.txt");
        let edges = vec![(0, 1), (42, 7)];
        let weights = vec![0.5, 2.25];
        write_text_weighted(&p, &edges, &weights).unwrap();
        let (e, w) = read_text_weighted(&p).unwrap();
        assert_eq!(e, edges);
        assert_eq!(w, weights);
        let (e, w) = read_auto_weighted(&p).unwrap();
        assert_eq!((e, w), (edges.clone(), weights));
        // unweighted readers still parse it, dropping the lane
        assert_eq!(read_text(&p).unwrap(), edges);
    }

    #[test]
    fn mixed_weight_columns_rejected() {
        let p = tmp("mix.txt");
        std::fs::write(&p, "1 2 0.5\n3 4\n").unwrap();
        assert!(read_text_weighted(&p).is_err());
        std::fs::write(&p, "1 2\n3 4 0.5\n").unwrap();
        assert!(read_text_weighted(&p).is_err());
    }

    #[test]
    fn text_tolerates_comments() {
        let p = tmp("c.txt");
        std::fs::write(&p, "# c\n% m\n\n1 2\n3\t4\n").unwrap();
        assert_eq!(read_text(&p).unwrap(), vec![(1, 2), (3, 4)]);
    }

    #[test]
    fn text_rejects_garbage() {
        let p = tmp("g.txt");
        std::fs::write(&p, "1 x\n").unwrap();
        assert!(read_text(&p).is_err());
        std::fs::write(&p, "1\n").unwrap();
        assert!(read_text(&p).is_err());
    }

    #[test]
    fn binary_roundtrip_and_auto() {
        let p = tmp("b.bin");
        let edges: Vec<Edge> = (0..5000u32).map(|i| (i, i.wrapping_mul(7) % 5000)).collect();
        write_binary(&p, &edges).unwrap();
        assert_eq!(read_binary(&p).unwrap(), edges);
        assert_eq!(read_auto(&p).unwrap(), edges);
        let (_, w) = read_binary_weighted(&p).unwrap();
        assert!(w.is_empty(), "v1 files have no weight lane");
    }

    #[test]
    fn weighted_binary_roundtrip_and_auto() {
        let p = tmp("bw.bin");
        let edges: Vec<Edge> = (0..2000u32).map(|i| (i, (i * 3) % 2000)).collect();
        let weights: Vec<f32> = (0..2000).map(|i| ((i % 8) + 1) as f32 * 0.25).collect();
        write_binary_weighted(&p, &edges, &weights).unwrap();
        let (e, w) = read_binary_weighted(&p).unwrap();
        assert_eq!(e, edges);
        assert_eq!(w, weights);
        let (e, w) = read_auto_weighted(&p).unwrap();
        assert_eq!((e.len(), w.len()), (2000, 2000));
        // unweighted reader drops the lane but keeps the edges
        assert_eq!(read_binary(&p).unwrap(), edges);
    }

    #[test]
    fn binary_detects_corruption() {
        let p = tmp("bc.bin");
        write_binary(&p, &[(1, 2), (3, 4)]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn stream_matches_bulk_read() {
        let p = tmp("s.bin");
        let edges: Vec<Edge> = (0..3000u32).map(|i| (i, (i * 13) % 3000)).collect();
        write_binary(&p, &edges).unwrap();
        let s = BinaryEdgeStream::open(&p).unwrap();
        assert_eq!(s.len_hint(), 3000);
        assert!(!s.weighted());
        let streamed: Vec<Edge> = s.map(|e| e.unwrap().0).collect();
        assert_eq!(streamed, edges);
    }

    #[test]
    fn weighted_stream_yields_weights() {
        let p = tmp("sw.bin");
        let edges: Vec<Edge> = vec![(1, 2), (3, 4), (5, 6)];
        let weights = vec![0.25f32, 1.5, 2.0];
        write_binary_weighted(&p, &edges, &weights).unwrap();
        let s = BinaryEdgeStream::open(&p).unwrap();
        assert!(s.weighted());
        let items: Vec<(Edge, Weight)> = s.map(|e| e.unwrap()).collect();
        assert_eq!(
            items,
            edges.iter().copied().zip(weights.iter().copied()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn stream_detects_corruption() {
        let p = tmp("sc.bin");
        write_binary(&p, &[(1, 2), (3, 4), (5, 6)]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[20] ^= 0xFF; // flip a payload byte
        std::fs::write(&p, &bytes).unwrap();
        let results: Vec<_> = BinaryEdgeStream::open(&p).unwrap().collect();
        assert!(results.iter().any(|r| r.is_err()), "corruption not surfaced");
    }

    #[test]
    fn stream_empty_list() {
        let p = tmp("se.bin");
        write_binary(&p, &[]).unwrap();
        let items: Vec<_> = BinaryEdgeStream::open(&p).unwrap().collect();
        assert!(items.is_empty());
    }

    #[test]
    fn binary_detects_truncation() {
        let p = tmp("bt.bin");
        write_binary(&p, &[(1, 2), (3, 4), (5, 6)]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
