//! Edge-list file formats.
//!
//! * **Text** — one `src dst` pair per line (whitespace separated, `#`
//!   comments), the lingua franca of SNAP/LAW downloads; the preprocessing
//!   pipeline ingests this.
//! * **Binary** — `GMEL` magic + u64 count + little-endian `u32,u32` pairs +
//!   CRC32; compact interchange between the generator and the preprocessor.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::Edge;

const BIN_MAGIC: &[u8; 4] = b"GMEL";
const BIN_VERSION: u32 = 1;

/// Write edges as text (`src<TAB>dst` per line).
pub fn write_text(path: &Path, edges: &[Edge]) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# graphmp edge list: src\tdst")?;
    for &(s, d) in edges {
        writeln!(w, "{s}\t{d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read a text edge list; tolerates comments and blank lines.
pub fn read_text(path: &Path) -> Result<Vec<Edge>> {
    let r = BufReader::new(File::open(path).with_context(|| path.display().to_string())?);
    let mut edges = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            bail!("line {}: expected two fields", lineno + 1);
        };
        let s: u32 = a.parse().with_context(|| format!("line {}: src", lineno + 1))?;
        let d: u32 = b.parse().with_context(|| format!("line {}: dst", lineno + 1))?;
        edges.push((s, d));
    }
    Ok(edges)
}

/// Write the binary edge-list format.
pub fn write_binary(path: &Path, edges: &[Edge]) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&BIN_VERSION.to_le_bytes())?;
    w.write_all(&(edges.len() as u64).to_le_bytes())?;
    let mut crc = crc32fast::Hasher::new();
    // chunked buffer to keep syscalls and hasher updates amortized
    let mut buf = Vec::with_capacity(8 * 1024);
    for &(s, d) in edges {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        if buf.len() >= 8 * 1024 {
            crc.update(&buf);
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        crc.update(&buf);
        w.write_all(&buf)?;
    }
    w.write_all(&crc.finalize().to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read the binary edge-list format, verifying magic/version/CRC.
pub fn read_binary(path: &Path) -> Result<Vec<Edge>> {
    let mut r = BufReader::new(File::open(path).with_context(|| path.display().to_string())?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != BIN_VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    let mut payload = vec![0u8; n * 8];
    r.read_exact(&mut payload)?;
    r.read_exact(&mut u32buf)?;
    let want_crc = u32::from_le_bytes(u32buf);
    let mut crc = crc32fast::Hasher::new();
    crc.update(&payload);
    if crc.finalize() != want_crc {
        bail!("{}: CRC mismatch (corrupt edge list)", path.display());
    }
    let mut edges = Vec::with_capacity(n);
    for chunk in payload.chunks_exact(8) {
        let s = u32::from_le_bytes(chunk[0..4].try_into().unwrap());
        let d = u32::from_le_bytes(chunk[4..8].try_into().unwrap());
        edges.push((s, d));
    }
    Ok(edges)
}

/// Streaming binary-edge-list reader: yields edges without materializing
/// the whole list (the external-memory preprocessing path).  CRC is
/// verified incrementally; a corrupt tail surfaces as an `Err` item.
pub struct BinaryEdgeStream {
    r: BufReader<File>,
    remaining: u64,
    crc: crc32fast::Hasher,
    path: std::path::PathBuf,
}

impl BinaryEdgeStream {
    pub fn open(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(File::open(path).with_context(|| path.display().to_string())?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != BIN_MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != BIN_VERSION {
            bail!("{}: unsupported version", path.display());
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        Ok(Self {
            r,
            remaining: u64::from_le_bytes(b8),
            crc: crc32fast::Hasher::new(),
            path: path.to_path_buf(),
        })
    }

    /// Total edges declared by the header (remaining at open time).
    pub fn len_hint(&self) -> u64 {
        self.remaining
    }
}

impl Iterator for BinaryEdgeStream {
    type Item = Result<Edge>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            // verify trailing CRC once
            let mut b4 = [0u8; 4];
            if let Err(e) = self.r.read_exact(&mut b4) {
                return Some(Err(e.into()));
            }
            let want = u32::from_le_bytes(b4);
            let got = std::mem::replace(&mut self.crc, crc32fast::Hasher::new()).finalize();
            self.remaining = u64::MAX; // terminal state
            if got != want {
                return Some(Err(anyhow::anyhow!(
                    "{}: CRC mismatch (corrupt edge stream)",
                    self.path.display()
                )));
            }
            return None;
        }
        if self.remaining == u64::MAX {
            return None;
        }
        let mut buf = [0u8; 8];
        match self.r.read_exact(&mut buf) {
            Ok(()) => {
                self.crc.update(&buf);
                self.remaining -= 1;
                Some(Ok((
                    u32::from_le_bytes(buf[0..4].try_into().unwrap()),
                    u32::from_le_bytes(buf[4..8].try_into().unwrap()),
                )))
            }
            Err(e) => {
                self.remaining = u64::MAX;
                Some(Err(e.into()))
            }
        }
    }
}

/// Auto-detect format by magic bytes.
pub fn read_auto(path: &Path) -> Result<Vec<Edge>> {
    let mut f = File::open(path).with_context(|| path.display().to_string())?;
    let mut magic = [0u8; 4];
    let got = f.read(&mut magic)?;
    drop(f);
    if got == 4 && &magic == BIN_MAGIC {
        read_binary(path)
    } else {
        read_text(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gmp_el_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn text_roundtrip() {
        let p = tmp("t.txt");
        let edges = vec![(0, 1), (42, 7), (7, 42)];
        write_text(&p, &edges).unwrap();
        assert_eq!(read_text(&p).unwrap(), edges);
        assert_eq!(read_auto(&p).unwrap(), edges);
    }

    #[test]
    fn text_tolerates_comments() {
        let p = tmp("c.txt");
        std::fs::write(&p, "# c\n% m\n\n1 2\n3\t4\n").unwrap();
        assert_eq!(read_text(&p).unwrap(), vec![(1, 2), (3, 4)]);
    }

    #[test]
    fn text_rejects_garbage() {
        let p = tmp("g.txt");
        std::fs::write(&p, "1 x\n").unwrap();
        assert!(read_text(&p).is_err());
        std::fs::write(&p, "1\n").unwrap();
        assert!(read_text(&p).is_err());
    }

    #[test]
    fn binary_roundtrip_and_auto() {
        let p = tmp("b.bin");
        let edges: Vec<Edge> = (0..5000u32).map(|i| (i, i.wrapping_mul(7) % 5000)).collect();
        write_binary(&p, &edges).unwrap();
        assert_eq!(read_binary(&p).unwrap(), edges);
        assert_eq!(read_auto(&p).unwrap(), edges);
    }

    #[test]
    fn binary_detects_corruption() {
        let p = tmp("bc.bin");
        write_binary(&p, &[(1, 2), (3, 4)]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn stream_matches_bulk_read() {
        let p = tmp("s.bin");
        let edges: Vec<Edge> = (0..3000u32).map(|i| (i, (i * 13) % 3000)).collect();
        write_binary(&p, &edges).unwrap();
        let s = BinaryEdgeStream::open(&p).unwrap();
        assert_eq!(s.len_hint(), 3000);
        let streamed: Vec<Edge> = s.map(|e| e.unwrap()).collect();
        assert_eq!(streamed, edges);
    }

    #[test]
    fn stream_detects_corruption() {
        let p = tmp("sc.bin");
        write_binary(&p, &[(1, 2), (3, 4), (5, 6)]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[20] ^= 0xFF; // flip a payload byte
        std::fs::write(&p, &bytes).unwrap();
        let results: Vec<_> = BinaryEdgeStream::open(&p).unwrap().collect();
        assert!(results.iter().any(|r| r.is_err()), "corruption not surfaced");
    }

    #[test]
    fn stream_empty_list() {
        let p = tmp("se.bin");
        write_binary(&p, &[]).unwrap();
        let items: Vec<_> = BinaryEdgeStream::open(&p).unwrap().collect();
        assert!(items.is_empty());
    }

    #[test]
    fn binary_detects_truncation() {
        let p = tmp("bt.bin");
        write_binary(&p, &[(1, 2), (3, 4), (5, 6)]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
