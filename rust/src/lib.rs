//! # GraphMP — semi-external-memory big graph processing
//!
//! A reproduction of *"GraphMP: An Efficient Semi-External-Memory Big Graph
//! Processing System on a Single Machine"* (Sun, Wen, Duong, Xiao — 2017)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: graph sharding, the
//!   vertex-centric sliding-window (VSW) engine, Bloom-filter selective
//!   scheduling, the compressed shard cache, all four out-of-core baseline
//!   engines (PSW/ESG/DSW/VSP) and the in-memory baseline.
//! * **Layer 2 (`python/compile/model.py`)** — the per-shard vertex-update
//!   programs (PageRank / SSSP / WCC) as JAX functions, AOT-lowered to HLO
//!   text artifacts at build time.
//! * **Layer 1 (`python/compile/kernels/`)** — the scatter-reduce hot-spot
//!   as Pallas kernels (one-hot-matmul segmented sum on the MXU, masked
//!   broadcast segmented min on the VPU).
//!
//! Python never runs on the iteration path: [`runtime`] loads the HLO
//! artifacts once via PJRT and executes them from the engine hot loop.
//!
//! ## Crate map
//!
//! | module        | role                                                     |
//! |---------------|----------------------------------------------------------|
//! | [`util`]      | substrates: PRNG, varint, JSON, thread pool, bench timer |
//! | [`graph`]     | edge lists, CSR, synthetic graph generators (R-MAT, …)   |
//! | [`bloom`]     | Bloom filters for selective scheduling (§II-D.1)         |
//! | [`storage`]   | on-disk formats, instrumented I/O, prefetch pipeline     |
//! | [`sharding`]  | vertex intervals + the 4-step preprocessing pipeline     |
//! | [`cache`]     | compressed shard cache, modes 1–4 (§II-D.2)              |
//! | [`apps`]      | vertex programs over typed value lanes (u32/u64/f32/f64): |
//! |               | PageRank, SSSP, WCC, BFS, SpMV(+f64), weighted SSSP,     |
//! |               | label propagation, max-degree                            |
//! | [`engine`]    | the VSW engine (Algorithm 1) + pipelined shard prefetch  |
//! | [`baselines`] | PSW / ESG / DSW / VSP out-of-core engines + in-memory    |
//! | [`iomodel`]   | Table II analytic I/O model                              |
//! | [`obs`]       | metrics registry + Prometheus exposition, flight recorder|
//! | [`runtime`]   | PJRT loading + execution of the AOT artifacts            |
//! | [`server`]    | `graphmp serve`: resident engine, sessions, admission    |
//! | [`cluster`]   | `graphmp partrun`: interval workers + barrier exchange   |
//! | [`coordinator`]| job specs, experiment drivers, report formatting        |
//!
//! ## The shard I/O pipeline
//!
//! The journal version of the paper (arXiv:1810.04334) overlaps shard
//! loading with computation; this crate reproduces that as a bounded
//! prefetch pipeline: `storage::prefetch` provides the in-flight gate and
//! ordered file read-ahead, `engine::vsw` runs an I/O pool that
//! Bloom-screens, reads and decompresses the next
//! [`engine::EngineConfig::prefetch_depth`] shards while the compute pool
//! updates the current ones, and [`engine::IterStats`] splits worker time
//! into `io_wait` vs `compute` so the overlap is measurable
//! (`benches/fig6_loading.rs`, `benches/fig7_periter.rs`).  Results are
//! bit-identical to synchronous loading for every thread count and depth
//! (`tests/prefetch_pipeline.rs`).
//!
//! ## The adaptive I/O governor
//!
//! The pipeline's three static knobs — read-ahead depth, cache byte
//! budget, file-order shard issue — collapse into one per-iteration
//! feedback loop under `--adaptive` ([`engine::Governor`]):
//!
//! * **window**: grows (×2) while workers stall on shard acquisition
//!   (`io_wait_fraction` above ~0.4), shrinks (−1) when compute-bound,
//!   clamped to `[1, --prefetch-max]`;
//! * **memory split**: a finite cache budget lends its unused bytes to the
//!   in-flight allowance and reclaims them as the cache fills, so the
//!   semi-external envelope holds with the window in motion;
//! * **schedule**: shards are issued hottest-first (Bloom active-source
//!   density + per-shard miss history); mode-1 cache residents never wait
//!   for a read-ahead slot (their hit is an `Arc` clone, not a fresh
//!   decode), and the same scores steer cache eviction away from hot
//!   shards.
//!
//! Decisions read only *completed* iterations, so results stay
//! bit-identical to every fixed configuration (`tests/governor_adaptive.rs`
//! and the determinism regression), while `VswEngine::memory_estimate`
//! reports the window's high-water mark so Fig 11 stays honest.  The CI
//! `bench-smoke` job records each PR's wall time / io-wait fraction / cache
//! hit ratio to `BENCH_pr.json` and gates >25 % regressions against the
//! committed `BENCH_baseline.json` ([`coordinator::benchjson`]).

pub mod apps;
pub mod baselines;
pub mod bloom;
pub mod cache;
pub mod cluster;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod iomodel;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sharding;
pub mod storage;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
