//! Weighted single-source shortest paths (Algorithm 2, `SSSP_Update` with
//! real `val(u,v)` — the journal version and NXgraph both evaluate this):
//!
//! ```text
//! d   = min_{u ∈ Γin(v)} src[u] + val(u,v)
//! new = min(d, old)
//! ```
//!
//! On an unweighted dataset every `val(u,v)` is 1 and the program is
//! bit-identical to [`super::Sssp`].  Path sums are per-path sequential f32
//! adds and the min-monoid is order-insensitive, so results are
//! bit-identical across every engine regardless of gather order.

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct WeightedSssp {
    pub source: VertexId,
}

impl VertexProgram for WeightedSssp {
    fn name(&self) -> &'static str {
        "wsssp"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
        if v == self.source {
            0.0
        } else {
            f32::INFINITY
        }
    }

    fn initially_active(&self, v: VertexId, _ctx: &ProgramContext) -> bool {
        v == self.source
    }

    #[inline]
    fn gather(&self, src_val: f32, _src_out_deg: u32, weight: Weight) -> f32 {
        src_val + weight
    }

    fn reduce(&self) -> Reduce {
        Reduce::Min
    }

    #[inline]
    fn apply(&self, reduced: f32, old: f32, _ctx: &ProgramContext) -> f32 {
        reduced.min(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::RelaxMin
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::PlusWeight
    }

    fn default_max_iters(&self) -> usize {
        10_000
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relaxes_along_weighted_path() {
        let s = WeightedSssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 3 };
        // path 0 -(0.5)-> 1 -(2.0)-> 2
        let mut vals = vec![0.0f32, f32::INFINITY, f32::INFINITY];
        let out_deg = vec![1u32, 1, 0];
        for _ in 0..3 {
            let next = vec![
                s.update_weighted(0, &[], &[], &vals, &out_deg, &ctx),
                s.update_weighted(1, &[0], &[0.5], &vals, &out_deg, &ctx),
                s.update_weighted(2, &[1], &[2.0], &vals, &out_deg, &ctx),
            ];
            vals = next;
        }
        assert_eq!(vals, vec![0.0, 0.5, 2.5]);
    }

    #[test]
    fn unit_weights_reduce_to_plain_sssp() {
        let w = WeightedSssp { source: 0 };
        let s = super::super::Sssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 4 };
        let vals = vec![0.0f32, 1.0, f32::INFINITY, f32::INFINITY];
        let out_deg = vec![1u32; 4];
        // empty weight slice = unit weights
        assert_eq!(
            w.update_weighted(2, &[1], &[], &vals, &out_deg, &ctx),
            s.update(2, &[1], &vals, &out_deg, &ctx)
        );
    }

    #[test]
    fn picks_the_lighter_path() {
        let s = WeightedSssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 3 };
        // two in-edges into v=2: via 0 (weight 5) and via 1 (dist 1 + 0.5)
        let vals = vec![0.0f32, 1.0, f32::INFINITY];
        let out_deg = vec![2u32, 1, 0];
        let got = s.update_weighted(2, &[0, 1], &[5.0, 0.5], &vals, &out_deg, &ctx);
        assert_eq!(got, 1.5);
    }
}
