//! Single-source shortest paths (Algorithm 2, `SSSP_Update`), unweighted
//! edges (`val(u,v) = 1` per §II-A):
//!
//! ```text
//! d   = min_{u ∈ Γin(v)} src[u] + 1
//! new = min(d, old)
//! ```

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct Sssp {
    pub source: VertexId,
}

impl VertexProgram for Sssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
        if v == self.source {
            0.0
        } else {
            f32::INFINITY
        }
    }

    fn initially_active(&self, v: VertexId, _ctx: &ProgramContext) -> bool {
        v == self.source
    }

    #[inline]
    fn gather(&self, src_val: f32, _src_out_deg: u32, _weight: Weight) -> f32 {
        src_val + 1.0
    }

    fn reduce(&self) -> Reduce {
        Reduce::Min
    }

    #[inline]
    fn apply(&self, reduced: f32, old: f32, _ctx: &ProgramContext) -> f32 {
        reduced.min(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::RelaxMin
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::PlusOne
    }

    fn default_max_iters(&self) -> usize {
        10_000 // runs to convergence; diameter-bounded
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relaxes_along_path() {
        let s = Sssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 3 };
        // path 0 -> 1 -> 2
        let mut vals = vec![0.0f32, f32::INFINITY, f32::INFINITY];
        let out_deg = vec![1u32, 1, 0];
        for _ in 0..3 {
            let next = vec![
                s.update(0, &[], &vals, &out_deg, &ctx),
                s.update(1, &[0], &vals, &out_deg, &ctx),
                s.update(2, &[1], &vals, &out_deg, &ctx),
            ];
            vals = next;
        }
        assert_eq!(vals, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn unreachable_stays_infinite() {
        let s = Sssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 2 };
        let vals = vec![0.0f32, f32::INFINITY];
        let out_deg = vec![0u32, 0];
        assert!(s.update(1, &[], &vals, &out_deg, &ctx).is_infinite());
    }

    #[test]
    fn never_increases_distance() {
        let s = Sssp::default();
        let ctx = ProgramContext { num_vertices: 2 };
        let vals = vec![5.0f32, 2.0];
        let out_deg = vec![1u32, 1];
        // in-neighbor offers 5+1=6 > old 2 => keep 2
        assert_eq!(s.update(1, &[0], &vals, &out_deg, &ctx), 2.0);
    }
}
