//! BFS levels — extension app (not in the paper's evaluation, but the
//! standard fourth benchmark of the systems it compares against).
//! Identical monoid structure to SSSP on unweighted graphs; kept separate so
//! ablations can use a program whose frontier is strictly level-synchronous.

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct Bfs {
    pub root: VertexId,
}

impl VertexProgram for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
        if v == self.root {
            0.0
        } else {
            f32::INFINITY
        }
    }

    fn initially_active(&self, v: VertexId, _ctx: &ProgramContext) -> bool {
        v == self.root
    }

    #[inline]
    fn gather(&self, src_val: f32, _src_out_deg: u32, _weight: Weight) -> f32 {
        src_val + 1.0
    }

    fn reduce(&self) -> Reduce {
        Reduce::Min
    }

    #[inline]
    fn apply(&self, reduced: f32, old: f32, _ctx: &ProgramContext) -> f32 {
        reduced.min(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::RelaxMin
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::PlusOne
    }

    fn default_max_iters(&self) -> usize {
        10_000
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_on_star() {
        let b = Bfs { root: 0 };
        let ctx = ProgramContext { num_vertices: 4 };
        let vals = vec![0.0f32, f32::INFINITY, f32::INFINITY, f32::INFINITY];
        let out_deg = vec![3u32, 0, 0, 0];
        for leaf in 1..4u32 {
            assert_eq!(b.update(leaf, &[0], &vals, &out_deg, &ctx), 1.0);
        }
    }
}
