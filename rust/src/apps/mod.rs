//! Vertex programs (paper §II-C.2, Algorithm 2).
//!
//! GraphMP's user API is a single pull-style `Update(v, SrcVertexArray)`
//! function.  Every application in the paper (and all extras here) factors
//! into three pieces the engine can exploit:
//!
//! * **gather** — per-in-edge contribution from the source's current value;
//! * **reduce** — a commutative monoid (sum or min) over contributions;
//! * **apply**  — combine the reduction with the vertex's old value.
//!
//! This factorization is exactly what lets the hot loop run as an AOT
//! kernel: gather happens on the L3 side (it needs the CSR walk + degree
//! array), reduce+apply are the L1/L2 artifact (`pr_shard`,
//! `relaxmin_shard`, `segsum_shard`).

pub mod bfs;
pub mod pagerank;
pub mod spmv;
pub mod sssp;
pub mod wcc;

pub use bfs::Bfs;
pub use pagerank::PageRank;
pub use spmv::SpMv;
pub use sssp::Sssp;
pub use wcc::Wcc;

use crate::graph::VertexId;

/// The reduction monoid of a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    Sum,
    Min,
}

impl Reduce {
    #[inline]
    pub fn identity(&self) -> f32 {
        match self {
            Reduce::Sum => 0.0,
            Reduce::Min => f32::INFINITY,
        }
    }

    #[inline]
    pub fn combine(&self, a: f32, b: f32) -> f32 {
        match self {
            Reduce::Sum => a + b,
            Reduce::Min => a.min(b),
        }
    }
}

/// Which AOT artifact computes reduce+apply for this program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// `pr_shard`: new = 0.15/N + 0.85·Σ contrib.
    PrAffine,
    /// `relaxmin_shard`: new = min(old, min contrib).
    RelaxMin,
    /// `segsum_shard`: new = Σ contrib.
    RawSum,
}

/// Shape of the gather function, used by the native backend to select a
/// monomorphized inner loop (a virtual call per *edge* costs ~2× on the
/// hot path — see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatherKind {
    /// `src_val / out_deg(src)` with 0 for dangling sources (PageRank).
    RankOverOutDeg,
    /// `src_val + 1` (SSSP/BFS on unit weights).
    PlusOne,
    /// `src_val` (WCC, SpMV).
    Identity,
    /// Anything else: the engine falls back to calling `gather` per edge.
    Custom,
}

/// Static context handed to programs.
#[derive(Debug, Clone, Copy)]
pub struct ProgramContext {
    pub num_vertices: u64,
}

/// A vertex-centric program (see module docs for the factorization).
pub trait VertexProgram: Sync {
    fn name(&self) -> &'static str;

    /// Initial value of vertex `v`.
    fn init(&self, v: VertexId, ctx: &ProgramContext) -> f32;

    /// Is `v` active before the first iteration?
    fn initially_active(&self, v: VertexId, ctx: &ProgramContext) -> bool;

    /// Contribution pulled along an in-edge from source `u`.
    fn gather(&self, src_val: f32, src_out_deg: u32) -> f32;

    fn reduce(&self) -> Reduce;

    /// Combine reduction result with the vertex's previous value.
    fn apply(&self, reduced: f32, old: f32, ctx: &ProgramContext) -> f32;

    /// AOT artifact implementing reduce+apply.
    fn kernel(&self) -> KernelKind;

    /// Gather-shape hint for the native backend's monomorphized loops.
    /// The default is correct for any program; overriding it is purely a
    /// performance optimization and must match `gather`'s semantics
    /// (checked by `engine::backend` tests).
    fn gather_kind(&self) -> GatherKind {
        GatherKind::Custom
    }

    /// Default iteration cap when the caller does not override it
    /// (PageRank-style programs never fully converge under float equality).
    fn default_max_iters(&self) -> usize {
        100
    }

    /// Reference `Update` semantics (Algorithm 2): single-vertex update
    /// from an in-neighbor slice.  Used by tests and the baselines.
    fn update(
        &self,
        v: VertexId,
        in_neighbors: &[VertexId],
        src: &[f32],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) -> f32 {
        let r = self.reduce();
        let mut acc = r.identity();
        for &u in in_neighbors {
            acc = r.combine(acc, self.gather(src[u as usize], out_deg[u as usize]));
        }
        self.apply(acc, src[v as usize], ctx)
    }
}

/// Look up a program by CLI name.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn VertexProgram>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "pagerank" | "pr" => Box::new(PageRank::default()),
        "sssp" => Box::new(Sssp::default()),
        "wcc" => Box::new(Wcc),
        "bfs" => Box::new(Bfs::default()),
        "spmv" => Box::new(SpMv::default()),
        other => anyhow::bail!("unknown app {other:?} (pagerank|sssp|wcc|bfs|spmv)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_monoids() {
        assert_eq!(Reduce::Sum.combine(Reduce::Sum.identity(), 3.0), 3.0);
        assert_eq!(Reduce::Min.combine(Reduce::Min.identity(), 3.0), 3.0);
        assert_eq!(Reduce::Min.combine(2.0, 3.0), 2.0);
    }

    #[test]
    fn by_name_resolves() {
        for n in ["pagerank", "pr", "sssp", "wcc", "bfs", "spmv"] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("nope").is_err());
    }
}
