//! Vertex programs (paper §II-C.2, Algorithm 2).
//!
//! GraphMP's user API is a single pull-style `Update(v, SrcVertexArray)`
//! function.  Every application in the paper (and all extras here) factors
//! into three pieces the engine can exploit:
//!
//! * **gather** — per-in-edge contribution from the source's current value
//!   and the edge's weight (`val(u,v)`, 1 on unweighted graphs);
//! * **reduce** — a commutative monoid (sum, min or max) over contributions;
//! * **apply**  — combine the reduction with the vertex's old value.
//!
//! This factorization is exactly what lets the hot loop run as an AOT
//! kernel: gather happens on the L3 side (it needs the CSR walk + degree
//! array), reduce+apply are the L1/L2 artifact (`pr_shard`,
//! `relaxmin_shard`, `segsum_shard`).
//!
//! ## Typed vertex state
//!
//! `VertexProgram<V>` is generic over the vertex-value lane
//! ([`VertexValue`]: `u32`/`u64`/`f32`/`f64`); the default parameter keeps
//! `dyn VertexProgram` meaning the classic `f32` programs.  [`AnyProgram`]
//! is the lane-erased handle the CLI and drivers dispatch on, and
//! [`REGISTRY`] is the single table every app name, alias and error message
//! derives from.

pub mod bfs;
pub mod labelprop;
pub mod maxdeg;
pub mod pagerank;
pub mod spmv;
pub mod sssp;
pub mod wcc;
pub mod wsssp;

pub use bfs::Bfs;
pub use labelprop::LabelProp;
pub use maxdeg::MaxDeg;
pub use pagerank::PageRank;
pub use spmv::{SpMv, SpMv64};
pub use sssp::Sssp;
pub use wcc::Wcc;
pub use wsssp::WeightedSssp;

pub use crate::graph::value::{Lane, VertexValue};
use crate::graph::{VertexId, Weight};

/// The reduction monoid of a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    Sum,
    Min,
    Max,
}

impl Reduce {
    #[inline]
    pub fn identity<V: VertexValue>(&self) -> V {
        match self {
            Reduce::Sum => V::vzero(),
            Reduce::Min => V::vmax_value(),
            Reduce::Max => V::vmin_value(),
        }
    }

    #[inline]
    pub fn combine<V: VertexValue>(&self, a: V, b: V) -> V {
        match self {
            Reduce::Sum => a.vadd(b),
            Reduce::Min => a.vmin(b),
            Reduce::Max => a.vmax(b),
        }
    }

    /// Is `apply(identity, old) == old` preserved under re-offered inputs?
    /// Min/Max programs fold monotonically into `old`, so engines may skip
    /// quiet sources (GridGraph row skipping); Sum programs recompute the
    /// full in-edge sum and must never skip.
    pub fn is_monotone(&self) -> bool {
        matches!(self, Reduce::Min | Reduce::Max)
    }
}

/// Which AOT artifact computes reduce+apply for this program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// `pr_shard`: new = 0.15/N + 0.85·Σ contrib.
    PrAffine,
    /// `relaxmin_shard`: new = min(old, min contrib).
    RelaxMin,
    /// `segsum_shard`: new = Σ contrib.
    RawSum,
    /// No AOT artifact; the xla backend falls back to the native loop.
    None,
}

/// Shape of the gather function, used by the native backend to select a
/// monomorphized inner loop (a virtual call per *edge* costs ~2× on the
/// hot path — see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatherKind {
    /// `src_val / out_deg(src)` with 0 for dangling sources (PageRank).
    RankOverOutDeg,
    /// `src_val + 1` (SSSP/BFS on unit weights).
    PlusOne,
    /// `src_val + val(u,v)` (weighted SSSP).
    PlusWeight,
    /// `src_val` (WCC, SpMV, label propagation).
    Identity,
    /// Anything else: the engine falls back to calling `gather` per edge.
    Custom,
}

/// Static context handed to programs.
#[derive(Debug, Clone, Copy)]
pub struct ProgramContext {
    pub num_vertices: u64,
}

/// A vertex-centric program over value lane `V` (see module docs for the
/// factorization).  The default `V = f32` keeps `dyn VertexProgram`
/// meaning the paper's float programs.
pub trait VertexProgram<V: VertexValue = f32>: Sync {
    fn name(&self) -> &'static str;

    /// Initial value of vertex `v`.
    fn init(&self, v: VertexId, ctx: &ProgramContext) -> V;

    /// Is `v` active before the first iteration?
    fn initially_active(&self, v: VertexId, ctx: &ProgramContext) -> bool;

    /// Contribution pulled along an in-edge from source `u` with edge
    /// weight `val(u,v)` (1 on unweighted graphs).
    fn gather(&self, src_val: V, src_out_deg: u32, weight: Weight) -> V;

    fn reduce(&self) -> Reduce;

    /// Combine reduction result with the vertex's previous value.
    fn apply(&self, reduced: V, old: V, ctx: &ProgramContext) -> V;

    /// AOT artifact implementing reduce+apply.
    fn kernel(&self) -> KernelKind;

    /// Gather-shape hint for the native backend's monomorphized loops.
    /// The default is correct for any program; overriding it is purely a
    /// performance optimization and must match `gather`'s semantics
    /// (checked by `engine::backend` tests).
    fn gather_kind(&self) -> GatherKind {
        GatherKind::Custom
    }

    /// Default iteration cap when the caller does not override it
    /// (PageRank-style programs never fully converge under float equality).
    fn default_max_iters(&self) -> usize {
        100
    }

    /// The `f32`-lane view of this program, if it is one — the xla backend
    /// only has artifacts for the float path and uses this to dispatch;
    /// other lanes fall back to the native loop.  `f32` programs should
    /// override this to `Some(self)`.
    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        None
    }

    /// Reference `Update` semantics (Algorithm 2) on unit weights: used by
    /// tests and the baselines for unweighted graphs.
    fn update(
        &self,
        v: VertexId,
        in_neighbors: &[VertexId],
        src: &[V],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) -> V {
        self.update_weighted(v, in_neighbors, &[], src, out_deg, ctx)
    }

    /// Reference `Update` semantics with explicit per-in-edge weights
    /// (empty ⇒ unit weights), parallel to `in_neighbors`.
    fn update_weighted(
        &self,
        v: VertexId,
        in_neighbors: &[VertexId],
        weights: &[Weight],
        src: &[V],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) -> V {
        let r = self.reduce();
        let mut acc = r.identity();
        for (j, &u) in in_neighbors.iter().enumerate() {
            let w = if weights.is_empty() { 1.0 } else { weights[j] };
            acc = r.combine(acc, self.gather(src[u as usize], out_deg[u as usize], w));
        }
        self.apply(acc, src[v as usize], ctx)
    }
}

/// A lane-erased vertex program — what [`by_name`] hands the CLI and
/// drivers.  Match on it (or use [`crate::engine::VswEngine::run_any`]) to
/// reach the typed engine paths.
pub enum AnyProgram {
    F32(Box<dyn VertexProgram<f32>>),
    F64(Box<dyn VertexProgram<f64>>),
    U32(Box<dyn VertexProgram<u32>>),
    U64(Box<dyn VertexProgram<u64>>),
}

impl AnyProgram {
    pub fn name(&self) -> &'static str {
        match self {
            AnyProgram::F32(p) => p.name(),
            AnyProgram::F64(p) => p.name(),
            AnyProgram::U32(p) => p.name(),
            AnyProgram::U64(p) => p.name(),
        }
    }

    pub fn lane(&self) -> Lane {
        match self {
            AnyProgram::F32(_) => Lane::F32,
            AnyProgram::F64(_) => Lane::F64,
            AnyProgram::U32(_) => Lane::U32,
            AnyProgram::U64(_) => Lane::U64,
        }
    }

    pub fn default_max_iters(&self) -> usize {
        match self {
            AnyProgram::F32(p) => p.default_max_iters(),
            AnyProgram::F64(p) => p.default_max_iters(),
            AnyProgram::U32(p) => p.default_max_iters(),
            AnyProgram::U64(p) => p.default_max_iters(),
        }
    }

    /// The program's reduction monoid — what incremental restart checks:
    /// Min/Max programs (whose `apply` folds the old value) re-converge
    /// from a prior fixpoint after insert-only mutations; Sum programs
    /// must recompute cold.
    pub fn reduce(&self) -> Reduce {
        match self {
            AnyProgram::F32(p) => p.reduce(),
            AnyProgram::F64(p) => p.reduce(),
            AnyProgram::U32(p) => p.reduce(),
            AnyProgram::U64(p) => p.reduce(),
        }
    }

    /// The program's gather shape — what Sum-lane incremental maintenance
    /// checks: a gather reading `src_out_deg` ([`GatherKind::RankOverOutDeg`]
    /// or the unknowable [`GatherKind::Custom`]) makes a mutated vertex's
    /// *surviving* out-edges change contribution too, so only
    /// degree-oblivious gathers recompute mutation destinations alone.
    pub fn gather_kind(&self) -> GatherKind {
        match self {
            AnyProgram::F32(p) => p.gather_kind(),
            AnyProgram::F64(p) => p.gather_kind(),
            AnyProgram::U32(p) => p.gather_kind(),
            AnyProgram::U64(p) => p.gather_kind(),
        }
    }

    /// Unwrap the classic float lane (legacy drivers); errors for typed
    /// programs.
    pub fn into_f32(self) -> anyhow::Result<Box<dyn VertexProgram<f32>>> {
        match self {
            AnyProgram::F32(p) => Ok(p),
            other => anyhow::bail!(
                "app {:?} runs on the {} lane, not f32",
                other.name(),
                other.lane().name()
            ),
        }
    }
}

/// One registry row: the single source of truth for an app's CLI name,
/// aliases, value lane and description.  [`by_name`]'s error message and
/// every driver's app list derive from this table — never hand-write the
/// name list anywhere else.
pub struct AppEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub lane: Lane,
    pub about: &'static str,
    pub make: fn() -> AnyProgram,
}

/// Every registered vertex program.
pub static REGISTRY: &[AppEntry] = &[
    AppEntry {
        name: "pagerank",
        aliases: &["pr"],
        lane: Lane::F32,
        about: "PageRank, damping 0.85 (paper Fig 8)",
        make: || AnyProgram::F32(Box::new(PageRank::default())),
    },
    AppEntry {
        name: "sssp",
        aliases: &[],
        lane: Lane::F32,
        about: "single-source shortest paths, unit weights (paper Fig 9)",
        make: || AnyProgram::F32(Box::new(Sssp::default())),
    },
    AppEntry {
        name: "wcc",
        aliases: &[],
        lane: Lane::F32,
        about: "weakly connected components via min-label (paper Fig 10)",
        make: || AnyProgram::F32(Box::new(Wcc)),
    },
    AppEntry {
        name: "bfs",
        aliases: &[],
        lane: Lane::F32,
        about: "BFS levels from a root",
        make: || AnyProgram::F32(Box::new(Bfs::default())),
    },
    AppEntry {
        name: "spmv",
        aliases: &[],
        lane: Lane::F32,
        about: "one sparse matrix-vector product",
        make: || AnyProgram::F32(Box::new(SpMv::default())),
    },
    AppEntry {
        name: "spmv64",
        aliases: &[],
        lane: Lane::F64,
        about: "SpMV on the f64 lane",
        make: || AnyProgram::F64(Box::new(SpMv64::default())),
    },
    AppEntry {
        name: "wsssp",
        aliases: &["weighted-sssp"],
        lane: Lane::F32,
        about: "weighted SSSP over the per-edge weight lane",
        make: || AnyProgram::F32(Box::new(WeightedSssp::default())),
    },
    AppEntry {
        name: "labelprop",
        aliases: &["lp"],
        lane: Lane::U64,
        about: "min-label propagation on u64 labels",
        make: || AnyProgram::U64(Box::new(LabelProp)),
    },
    AppEntry {
        name: "maxdeg",
        aliases: &["degcent"],
        lane: Lane::U32,
        about: "max reachable out-degree on u32 (degree-centrality style)",
        make: || AnyProgram::U32(Box::new(MaxDeg)),
    },
];

/// `"pagerank|sssp|..."` — derived from [`REGISTRY`], used by error
/// messages and usage text so the list can never drift from the table.
pub fn app_names() -> String {
    REGISTRY.iter().map(|e| e.name).collect::<Vec<_>>().join("|")
}

/// Look up a program by CLI name or alias.
pub fn by_name(name: &str) -> anyhow::Result<AnyProgram> {
    let lower = name.to_ascii_lowercase();
    for entry in REGISTRY {
        if entry.name == lower || entry.aliases.contains(&lower.as_str()) {
            return Ok((entry.make)());
        }
    }
    anyhow::bail!("unknown app {name:?} ({})", app_names())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_monoids() {
        assert_eq!(Reduce::Sum.combine(Reduce::Sum.identity(), 3.0f32), 3.0);
        assert_eq!(Reduce::Min.combine(Reduce::Min.identity(), 3.0f32), 3.0);
        assert_eq!(Reduce::Min.combine(2.0f32, 3.0), 2.0);
        assert_eq!(Reduce::Max.combine(Reduce::Max.identity(), 3u32), 3);
        assert_eq!(Reduce::Max.combine(5u64, 3), 5);
        assert!(Reduce::Min.is_monotone() && Reduce::Max.is_monotone());
        assert!(!Reduce::Sum.is_monotone());
    }

    #[test]
    fn by_name_resolves_every_registry_row_and_alias() {
        for entry in REGISTRY {
            let p = by_name(entry.name).unwrap();
            assert_eq!(p.name(), entry.name);
            assert_eq!(p.lane(), entry.lane);
            for alias in entry.aliases {
                assert_eq!(by_name(alias).unwrap().name(), entry.name, "{alias}");
            }
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn unknown_app_error_lists_registry_names() {
        // the satellite fix: the error message must come from the table,
        // so every registered name appears in it
        let msg = format!("{:#}", by_name("zzz").unwrap_err());
        for entry in REGISTRY {
            assert!(msg.contains(entry.name), "error message missing {}", entry.name);
        }
    }

    #[test]
    fn into_f32_rejects_typed_lanes() {
        assert!(by_name("pagerank").unwrap().into_f32().is_ok());
        assert!(by_name("labelprop").unwrap().into_f32().is_err());
        assert!(by_name("maxdeg").unwrap().into_f32().is_err());
    }
}
