//! SpMV — `y = Aᵀ x` over the adjacency matrix, one iteration per call.
//!
//! Extension app exposing the raw segmented-sum artifact: GraphMat (the
//! paper's in-memory comparator) maps *all* programs to SpMV, so having the
//! primitive as a first-class program lets the Fig 6/7 benches compare
//! like-for-like.  `x` is the init vector (deterministic per `seed`).
//!
//! [`SpMv64`] is the same program on the `f64` lane — the double-precision
//! witness of the typed `VertexProgram` API (no AOT artifact exists for
//! f64, so the xla backend falls back to the native loop).

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};
use crate::util::hash::hash64_seeded;

#[derive(Debug, Clone, Copy)]
pub struct SpMv {
    pub seed: u64,
}

impl Default for SpMv {
    fn default() -> Self {
        Self { seed: 1 }
    }
}

impl VertexProgram for SpMv {
    fn name(&self) -> &'static str {
        "spmv"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
        // deterministic pseudo-random x vector in [0,1)
        (hash64_seeded(v as u64, self.seed) >> 40) as f32 / (1u64 << 24) as f32
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: f32, _src_out_deg: u32, _weight: Weight) -> f32 {
        src_val
    }

    fn reduce(&self) -> Reduce {
        Reduce::Sum
    }

    #[inline]
    fn apply(&self, reduced: f32, _old: f32, _ctx: &ProgramContext) -> f32 {
        reduced
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::RawSum
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::Identity
    }

    fn default_max_iters(&self) -> usize {
        1
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

/// SpMV on the `f64` lane (same deterministic `x`, widened).
#[derive(Debug, Clone, Copy)]
pub struct SpMv64 {
    pub seed: u64,
}

impl Default for SpMv64 {
    fn default() -> Self {
        Self { seed: 1 }
    }
}

impl VertexProgram<f64> for SpMv64 {
    fn name(&self) -> &'static str {
        "spmv64"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f64 {
        (hash64_seeded(v as u64, self.seed) >> 40) as f64 / (1u64 << 24) as f64
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: f64, _src_out_deg: u32, _weight: Weight) -> f64 {
        src_val
    }

    fn reduce(&self) -> Reduce {
        Reduce::Sum
    }

    #[inline]
    fn apply(&self, reduced: f64, _old: f64, _ctx: &ProgramContext) -> f64 {
        reduced
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::None
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::Identity
    }

    fn default_max_iters(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_step_is_matrix_vector_product() {
        let s = SpMv { seed: 3 };
        let ctx = ProgramContext { num_vertices: 3 };
        let x: Vec<f32> = (0..3).map(|v| s.init(v, &ctx)).collect();
        let out_deg = vec![2u32, 1, 0];
        // v=2 has in-neighbors {0, 1}
        let y2 = s.update(2, &[0, 1], &x, &out_deg, &ctx);
        assert!((y2 - (x[0] + x[1])).abs() < 1e-6);
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let s = SpMv { seed: 9 };
        let ctx = ProgramContext { num_vertices: 10 };
        for v in 0..10u32 {
            let a = s.init(v, &ctx);
            assert_eq!(a, s.init(v, &ctx));
            assert!((0.0..1.0).contains(&a));
        }
    }

    #[test]
    fn f64_twin_matches_f32_to_single_precision() {
        let s32 = SpMv { seed: 5 };
        let s64 = SpMv64 { seed: 5 };
        let ctx = ProgramContext { num_vertices: 8 };
        let x64: Vec<f64> = (0..8).map(|v| s64.init(v, &ctx)).collect();
        let x32: Vec<f32> = (0..8).map(|v| s32.init(v, &ctx)).collect();
        for (a, b) in x64.iter().zip(&x32) {
            assert!((a - *b as f64).abs() < 1e-7, "{a} vs {b}");
        }
        let y = s64.update(2, &[0, 1], &x64, &[1, 1, 0, 0, 0, 0, 0, 0], &ctx);
        assert!((y - (x64[0] + x64[1])).abs() < 1e-12);
    }
}
