//! Weakly connected components (Algorithm 2, `WCC_Update`): label
//! propagation of the minimum component id.
//!
//! ```text
//! g   = min_{u ∈ Γin(v)} src[u]
//! new = min(g, old)
//! ```
//!
//! NOTE on "weakly": propagating along in-edges only computes the minimum
//! label over vertices that can *reach* v. For true weak connectivity the
//! preprocessing step symmetrizes the graph (`graphmp preprocess
//! --symmetrize`), exactly how GraphChi/X-Stream benchmarks run WCC; the
//! engine itself is direction-agnostic.

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct Wcc;

impl VertexProgram for Wcc {
    fn name(&self) -> &'static str {
        "wcc"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
        v as f32
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: f32, _src_out_deg: u32, _weight: Weight) -> f32 {
        src_val
    }

    fn reduce(&self) -> Reduce {
        Reduce::Min
    }

    #[inline]
    fn apply(&self, reduced: f32, old: f32, _ctx: &ProgramContext) -> f32 {
        reduced.min(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::RelaxMin
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::Identity
    }

    fn default_max_iters(&self) -> usize {
        10_000
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_propagate_to_min() {
        let w = Wcc;
        let ctx = ProgramContext { num_vertices: 4 };
        // chain 0 <-> 1 <-> 2, isolated 3 (symmetrized adjacency)
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![0, 2], vec![1], vec![]];
        let out_deg = vec![1u32, 2, 1, 0];
        let mut vals: Vec<f32> = (0..4).map(|v| w.init(v, &ctx)).collect();
        for _ in 0..4 {
            vals = (0..4)
                .map(|v| w.update(v, &adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
        }
        assert_eq!(vals, vec![0.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn component_ids_exact_in_f32() {
        // ids up to 2^24 are exact in f32; our scaled datasets stay below
        let w = Wcc;
        let ctx = ProgramContext { num_vertices: 1 << 24 };
        let id = (1 << 24) - 1;
        assert_eq!(w.init(id, &ctx) as u32, id);
    }
}
