//! MaxDeg — maximum reachable out-degree, on the `u32` lane with the `Max`
//! reduction: a degree-centrality / k-core-style integer workload.
//!
//! ```text
//! g   = max_{u ∈ Γin(v)} max(src[u], out_deg(u))
//! new = max(g, old)
//! ```
//!
//! At the fixpoint, `value[v]` is the largest out-degree among all vertices
//! with a directed path to `v` (0 for vertices with no in-path — including
//! isolated ones).  It is the `Max`-monoid witness of the generic API: the
//! reduction is order-insensitive and integer-exact, so every engine must
//! agree bit-for-bit, and it exercises the `src_out_deg` gather argument
//! that PageRank alone used before.

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct MaxDeg;

impl VertexProgram<u32> for MaxDeg {
    fn name(&self) -> &'static str {
        "maxdeg"
    }

    fn init(&self, _v: VertexId, _ctx: &ProgramContext) -> u32 {
        0
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: u32, src_out_deg: u32, _weight: Weight) -> u32 {
        src_val.max(src_out_deg)
    }

    fn reduce(&self) -> Reduce {
        Reduce::Max
    }

    #[inline]
    fn apply(&self, reduced: u32, old: u32, _ctx: &ProgramContext) -> u32 {
        reduced.max(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::None
    }

    fn default_max_iters(&self) -> usize {
        10_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propagates_largest_upstream_degree() {
        let md = MaxDeg;
        let ctx = ProgramContext { num_vertices: 4 };
        // 0 -> 1 -> 2 -> 3 with out_deg = [3, 1, 1, 0] (0 has extra edges)
        let adj: Vec<Vec<u32>> = vec![vec![], vec![0], vec![1], vec![2]];
        let out_deg = vec![3u32, 1, 1, 0];
        let mut vals: Vec<u32> = (0..4).map(|v| md.init(v, &ctx)).collect();
        for _ in 0..4 {
            vals = (0..4)
                .map(|v| md.update(v, &adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
        }
        // the hub's degree 3 reaches every downstream vertex
        assert_eq!(vals, vec![0, 3, 3, 3]);
    }

    #[test]
    fn isolated_vertices_stay_zero() {
        let md = MaxDeg;
        let ctx = ProgramContext { num_vertices: 2 };
        let vals = vec![0u32, 0];
        assert_eq!(md.update(1, &[], &vals, &[0, 0], &ctx), 0);
    }

    #[test]
    fn fixpoint_is_stable() {
        let md = MaxDeg;
        let ctx = ProgramContext { num_vertices: 2 };
        // once old >= every offered contribution, the value never moves
        let vals = vec![5u32, 7];
        assert_eq!(md.update(1, &[0], &vals, &[2, 0], &ctx), 7);
    }
}
