//! PageRank (Algorithm 2, `PR_Update`):
//!
//! ```text
//! s = Σ_{u ∈ Γin(v)} src[u] / out_deg(u)
//! new = 0.15 / |V| + 0.85 · s
//! ```
//!
//! Dangling vertices (out-degree 0) contribute nothing, matching the paper's
//! formulation (no dangling-mass redistribution).

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy)]
pub struct PageRank {
    pub damping: f32,
}

impl Default for PageRank {
    fn default() -> Self {
        Self { damping: 0.85 }
    }
}

impl VertexProgram for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, _v: VertexId, ctx: &ProgramContext) -> f32 {
        1.0 / ctx.num_vertices.max(1) as f32
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: f32, src_out_deg: u32, _weight: Weight) -> f32 {
        if src_out_deg == 0 {
            0.0
        } else {
            src_val / src_out_deg as f32
        }
    }

    fn reduce(&self) -> Reduce {
        Reduce::Sum
    }

    #[inline]
    fn apply(&self, reduced: f32, _old: f32, ctx: &ProgramContext) -> f32 {
        (1.0 - self.damping) / ctx.num_vertices.max(1) as f32 + self.damping * reduced
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::PrAffine
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::RankOverOutDeg
    }

    fn default_max_iters(&self) -> usize {
        // the paper runs 10 iterations for Fig 8-10 and 200 for Fig 5
        10
    }

    fn as_f32_program(&self) -> Option<&dyn VertexProgram<f32>> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_cycle_fixpoint() {
        // 0 <-> 1: symmetric, rank stays 0.5 each
        let pr = PageRank::default();
        let ctx = ProgramContext { num_vertices: 2 };
        let src = vec![0.5f32, 0.5];
        let out_deg = vec![1u32, 1];
        let v0 = pr.update(0, &[1], &src, &out_deg, &ctx);
        assert!((v0 - 0.5).abs() < 1e-6, "{v0}");
    }

    #[test]
    fn sink_gets_teleport_only() {
        let pr = PageRank::default();
        let ctx = ProgramContext { num_vertices: 4 };
        let src = vec![0.25f32; 4];
        let out_deg = vec![1u32; 4];
        let v = pr.update(2, &[], &src, &out_deg, &ctx);
        assert!((v - 0.15 / 4.0).abs() < 1e-7);
    }

    #[test]
    fn dangling_source_contributes_zero() {
        let pr = PageRank::default();
        assert_eq!(pr.gather(0.7, 0, 1.0), 0.0);
    }

    #[test]
    fn ranks_sum_near_one_on_strongly_connected() {
        // directed 4-cycle, iterate the reference update to fixpoint
        let pr = PageRank::default();
        let ctx = ProgramContext { num_vertices: 4 };
        let adj: Vec<Vec<u32>> = vec![vec![3], vec![0], vec![1], vec![2]];
        let out_deg = vec![1u32; 4];
        let mut vals = vec![0.25f32; 4];
        for _ in 0..50 {
            let next: Vec<f32> = (0..4)
                .map(|v| pr.update(v, &adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
            vals = next;
        }
        let sum: f32 = vals.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
    }
}
