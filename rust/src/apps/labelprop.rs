//! Label propagation on the `u64` lane: every vertex starts with its own
//! id as label and repeatedly adopts the minimum label among itself and its
//! in-neighbors — the typed-integer workload NXgraph (arXiv:1510.06916)
//! evaluates, and the `u64` witness of the generic `VertexProgram` API.
//!
//! Structurally this is WCC's min-label fixpoint, but on exact 64-bit
//! labels there is no `2^24` float-precision ceiling: label spaces of any
//! size propagate exactly, and integer equality makes convergence
//! bit-sharp on every engine.

use super::{KernelKind, ProgramContext, Reduce, VertexProgram};
use crate::graph::{VertexId, Weight};

#[derive(Debug, Clone, Copy, Default)]
pub struct LabelProp;

impl VertexProgram<u64> for LabelProp {
    fn name(&self) -> &'static str {
        "labelprop"
    }

    fn init(&self, v: VertexId, _ctx: &ProgramContext) -> u64 {
        v as u64
    }

    fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
        true
    }

    #[inline]
    fn gather(&self, src_val: u64, _src_out_deg: u32, _weight: Weight) -> u64 {
        src_val
    }

    fn reduce(&self) -> Reduce {
        Reduce::Min
    }

    #[inline]
    fn apply(&self, reduced: u64, old: u64, _ctx: &ProgramContext) -> u64 {
        reduced.min(old)
    }

    fn kernel(&self) -> KernelKind {
        KernelKind::None
    }

    fn gather_kind(&self) -> super::GatherKind {
        super::GatherKind::Identity
    }

    fn default_max_iters(&self) -> usize {
        10_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_propagate_to_min() {
        let lp = LabelProp;
        let ctx = ProgramContext { num_vertices: 4 };
        // chain 0 <-> 1 <-> 2, isolated 3 (symmetrized adjacency)
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![0, 2], vec![1], vec![]];
        let out_deg = vec![1u32, 2, 1, 0];
        let mut vals: Vec<u64> = (0..4).map(|v| lp.init(v, &ctx)).collect();
        for _ in 0..4 {
            vals = (0..4)
                .map(|v| lp.update(v, &adj[v as usize], &vals, &out_deg, &ctx))
                .collect();
        }
        assert_eq!(vals, vec![0, 0, 0, 3]);
    }

    #[test]
    fn labels_beyond_f32_precision_stay_exact() {
        // ids above 2^24 are not exact in f32 (the Wcc ceiling); the u64
        // lane carries them bit-exactly
        let lp = LabelProp;
        let ctx = ProgramContext { num_vertices: 1 << 26 };
        let big = (1u32 << 26) - 1;
        let smaller = (1u64 << 26) - 2;
        assert_eq!(lp.init(big, &ctx), big as u64);
        assert_eq!(lp.apply(smaller, big as u64, &ctx), smaller);
    }
}
