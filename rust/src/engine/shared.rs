//! `SharedSlice`: disjoint-interval concurrent writes without locks.
//!
//! The lock-free property of §II-C.3: "GraphMP only uses one CPU core to
//! process a shard for updating its associated vertices … DstVertexArray[v]
//! is computed and written by a single CPU core", so no atomics are needed.
//! This wrapper encodes that argument: writers may only touch the interval
//! their shard owns; intervals are disjoint by construction
//! (`Property::intervals` partitions the vertex space).

use std::cell::UnsafeCell;

/// A slice writable from multiple threads under the caller-guaranteed
/// invariant that no two threads write overlapping index ranges and no one
/// reads a range while it may be written.
pub struct SharedSlice<'a, T> {
    data: &'a [UnsafeCell<T>],
}

unsafe impl<T: Send + Sync> Sync for SharedSlice<'_, T> {}

impl<'a, T: Copy> SharedSlice<'a, T> {
    /// Wrap a mutable slice for the duration of a parallel phase.
    pub fn new(data: &'a mut [T]) -> Self {
        // SAFETY: &mut guarantees exclusivity; UnsafeCell<T> has the same
        // layout as T, so the cast is valid.
        let ptr = data.as_mut_ptr() as *const UnsafeCell<T>;
        Self { data: unsafe { std::slice::from_raw_parts(ptr, data.len()) } }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Write `value` at `i`.
    ///
    /// # Safety
    /// The caller must guarantee `i` is in an index range owned exclusively
    /// by the current thread for this phase (the shard's vertex interval).
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        *self.data[i].get() = value;
    }

    /// Copy `values` into `[start, start+len)`.
    ///
    /// # Safety
    /// Same exclusivity contract as [`Self::write`].
    #[inline]
    pub unsafe fn write_range(&self, start: usize, values: &[T]) {
        for (k, &v) in values.iter().enumerate() {
            *self.data[start + k].get() = v;
        }
    }

    /// Read the value at `i`.
    ///
    /// # Safety
    /// No concurrent writer may own `i` during this phase.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T {
        *self.data[i].get()
    }

    /// Exclusive mutable view of `[start, start+len)` — lets a shard (or
    /// intra-shard chunk) worker write its results in place instead of
    /// materializing a scratch vector and copying it in.
    ///
    /// # Safety
    /// Same exclusivity contract as [`Self::write`]: the caller must own
    /// the whole range for the duration of the borrow, with no concurrent
    /// reader or writer touching it.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.data.len());
        if len == 0 {
            return &mut [];
        }
        std::slice::from_raw_parts_mut(self.data[start].get(), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::parallel_for;

    #[test]
    fn disjoint_parallel_writes() {
        let n = 10_000;
        let mut data = vec![0u32; n];
        {
            let shared = SharedSlice::new(&mut data);
            // 10 "shards" of 1000 vertices each
            parallel_for(4, 10, |shard| {
                let lo = shard * 1000;
                for i in 0..1000 {
                    unsafe { shared.write(lo + i, (shard * 1000 + i) as u32) };
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn slice_mut_gives_disjoint_parallel_windows() {
        let n = 8000;
        let mut data = vec![0u32; n];
        {
            let shared = SharedSlice::new(&mut data);
            parallel_for(4, 8, |chunk| {
                let out = unsafe { shared.slice_mut(chunk * 1000, 1000) };
                for (k, slot) in out.iter_mut().enumerate() {
                    *slot = (chunk * 1000 + k) as u32;
                }
            });
            assert!(unsafe { shared.slice_mut(n, 0) }.is_empty());
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn write_range_copies() {
        let mut data = vec![0f32; 8];
        {
            let shared = SharedSlice::new(&mut data);
            unsafe { shared.write_range(2, &[1.0, 2.0, 3.0]) };
        }
        assert_eq!(data, vec![0.0, 0.0, 1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
    }
}
