//! The adaptive I/O governor — one feedback loop in place of three knobs.
//!
//! PR 1's prefetch pipeline exposed three static tuning parameters: the
//! read-ahead window (`prefetch_depth`), the cache byte budget, and the
//! (implicit, file-order) shard schedule.  Each is machine- and
//! workload-dependent: a window that hides a cold HDD's latency wastes
//! memory on a warm NVMe cache, and file-order read-ahead spends its slots
//! on shards the cache would have served for free.  NXgraph
//! (arXiv:1510.06916) makes the same observation for whole strategies —
//! picking adaptively from observed conditions is what makes a
//! single-machine system robust across hardware.
//!
//! The governor closes the loop per iteration, using **only prior-iteration
//! statistics** so every decision is a deterministic function of completed
//! work (results stay bit-identical to any fixed configuration —
//! `tests/prefetch_pipeline.rs` proves it):
//!
//! 1. **Adaptive window** ([`Governor::observe`] / [`Governor::plan_window`])
//!    — after each iteration the engine reports the workers' `io_wait` vs
//!    `compute` split ([`crate::engine::IterStats`]).  When the fraction of
//!    time stalled on shard acquisition exceeds [`GovernorConfig::grow_threshold`]
//!    the window doubles (slow-start style: stalls mean the pipeline is
//!    starved, so react fast); when it falls below
//!    [`GovernorConfig::shrink_threshold`] the window shrinks by one (the
//!    pipeline is already ahead; release memory gently).  The window is
//!    clamped to `[1, max_depth]`.
//!
//! 2. **Cache-budget loan** — a finite cache budget is part of the
//!    semi-external memory envelope.  Unused cache bytes are lent to the
//!    prefetch in-flight allowance (`extra slots = lendable / shard bytes`)
//!    and reclaimed automatically as the cache fills, because
//!    [`Governor::plan_window`] re-reads the lendable amount every
//!    iteration.  An unbounded or disabled cache imposes no loan constraint
//!    (`lendable = None`).
//!
//! 3. **Priority schedule** ([`Governor::schedule`]) — shards are issued to
//!    the I/O pool hottest-first instead of in file order: uncached shards
//!    ranked by the Bloom screen's active-source density (plus accumulated
//!    miss history) come first, cache-resident shards last.  Residents
//!    whose hit materializes no new decoded bytes additionally never
//!    *wait* for a read-ahead slot: mode-1 (a clone of the cached
//!    `Arc<Csr>`) and, under the compressed-domain gather, delta-varint
//!    (streamed straight from the slot's `Arc`-shared payload).  Byte
//!    codecs decompress a payload-sized buffer per hit and therefore stay
//!    gated.  The same scores feed
//!    [`crate::cache::ShardCache::set_priorities`], steering eviction away
//!    from hot shards.
//!
//! With `adaptive = false` every method degenerates to the fixed PR 1
//! behavior: constant window, identity schedule, no gate bypass.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::bloom::{BloomFilter, Digest};
use crate::cache::ShardCache;

/// Tuning envelope for the governor (defaults are deliberately coarse —
/// the feedback loop, not the constants, does the work).
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Master switch; `false` freezes every decision at the fixed-knob
    /// behavior.
    pub adaptive: bool,
    /// Starting read-ahead window (the engine's `prefetch_depth`).
    pub initial_depth: usize,
    /// Hard ceiling for the window (`--prefetch-max`).
    pub max_depth: usize,
    /// Grow the window when the prior iteration's io-wait fraction exceeds
    /// this (workers are starving on acquisition).
    pub grow_threshold: f64,
    /// Shrink the window when the fraction falls below this (the pipeline
    /// is comfortably ahead; hand memory back).
    pub shrink_threshold: f64,
}

impl GovernorConfig {
    pub fn from_engine(adaptive: bool, prefetch_depth: usize, prefetch_max: usize) -> Self {
        Self {
            adaptive,
            initial_depth: prefetch_depth,
            max_depth: prefetch_max.max(1),
            grow_threshold: 0.4,
            shrink_threshold: 0.15,
        }
    }
}

/// Per-run adaptive state.  All interior-mutable so the engine can hold the
/// governor behind `&self` alongside its thread pools.
pub struct Governor {
    cfg: GovernorConfig,
    /// Current window (next iteration's in-flight budget before the loan
    /// clamp).
    depth: AtomicUsize,
    /// Largest window ever planned — the honest input for
    /// `VswEngine::memory_estimate`.
    high_water: AtomicUsize,
    /// Decoded size of the largest shard, used to convert lent cache bytes
    /// into whole read-ahead slots.
    shard_bytes: usize,
}

impl Governor {
    pub fn new(cfg: GovernorConfig, max_shard_bytes: usize) -> Self {
        let initial = if cfg.adaptive {
            cfg.initial_depth.clamp(1, cfg.max_depth)
        } else {
            cfg.initial_depth
        };
        Self {
            cfg,
            depth: AtomicUsize::new(initial),
            high_water: AtomicUsize::new(initial),
            shard_bytes: max_shard_bytes.max(1),
        }
    }

    pub fn is_adaptive(&self) -> bool {
        self.cfg.adaptive
    }

    /// Current raw window (before the per-iteration loan clamp).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Largest window any iteration was planned with.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Decide this iteration's in-flight window.  `lendable` is the cache's
    /// unused budget in bytes when the cache has a *finite* budget (the loan
    /// source), or `None` when the cache is disabled or unbounded (no loan
    /// constraint — the envelope is `max_depth` alone).
    ///
    /// The base window (`initial_depth`) is always honored: the loan only
    /// gates growth *beyond* the configuration the user asked for, so a
    /// filling cache reclaims exactly the slots it lent.
    pub fn plan_window(&self, lendable: Option<usize>) -> usize {
        if !self.cfg.adaptive {
            return self.cfg.initial_depth;
        }
        let base = self.cfg.initial_depth.clamp(1, self.cfg.max_depth);
        let mut window = self.depth.load(Ordering::Relaxed).clamp(1, self.cfg.max_depth);
        if let Some(lendable) = lendable {
            let lent_slots = lendable / self.shard_bytes;
            window = window.min(base.saturating_add(lent_slots)).max(1);
        }
        self.high_water.fetch_max(window, Ordering::Relaxed);
        window
    }

    /// Feed back one completed iteration's worker-time split.  Pure
    /// function of prior-iteration stats: the *decision* is deterministic
    /// given the measurements, and no decision can alter results — only
    /// when bytes move.
    pub fn observe(&self, io_wait_ns: u64, compute_ns: u64) {
        if !self.cfg.adaptive {
            return;
        }
        let total = io_wait_ns + compute_ns;
        if total == 0 {
            return;
        }
        let frac = io_wait_ns as f64 / total as f64;
        let cur = self.depth.load(Ordering::Relaxed);
        let next = if frac > self.cfg.grow_threshold {
            (cur * 2).clamp(1, self.cfg.max_depth)
        } else if frac < self.cfg.shrink_threshold {
            cur.saturating_sub(1).max(1)
        } else {
            cur
        };
        self.depth.store(next, Ordering::Relaxed);
    }

    /// Priority score for one shard: higher = read sooner.  Composed of the
    /// Bloom screen's active-source density (dominant term) and the cache's
    /// per-shard miss history (tie-breaker that keeps historically
    /// disk-bound shards early even before selective scheduling engages).
    ///
    /// Takes the engine's *pre-hashed* active set: each active vertex is
    /// hashed into a [`Digest`] once per iteration and that digest array
    /// is reused by every shard's density probe here **and** every
    /// screening probe in the engine — without it the scheduler alone
    /// re-hashed every active vertex `shards × k` times per iteration.
    fn score(
        &self,
        shard: usize,
        selective_now: bool,
        digests: &[Digest],
        blooms: &[BloomFilter],
        cache: &ShardCache,
    ) -> u64 {
        let density = if selective_now && !digests.is_empty() {
            // |active ∩ bloom| / |active| in per-mille; the selective
            // threshold guarantees `active` is small here, so the probe is
            // cheap
            let hits = blooms[shard].count_contained_digest(digests) as u64;
            hits * 1000 / digests.len() as u64
        } else {
            // activation too high for the Bloom screen to discriminate:
            // every shard is (almost surely) active, rank on history alone
            1000
        };
        let (_, misses) = cache.shard_history(shard);
        density * 1_000_000 + misses.min(999_999)
    }

    /// Compute this iteration's shard issue order (a permutation of
    /// `0..num_shards`): hot uncached shards first (score descending, shard
    /// id ascending for determinism), cache-resident shards last.  Also
    /// installs the scores as the cache's eviction priorities so a
    /// over-budget cache sheds its coldest shards first.
    ///
    /// Non-adaptive mode returns file order — bit-for-bit the PR 1 issue
    /// sequence.
    ///
    /// `shard_epochs` is the calling snapshot's per-shard file-epoch table
    /// (residency is epoch-keyed; see [`ShardCache::is_resident`]).
    pub fn schedule(
        &self,
        num_shards: usize,
        selective_now: bool,
        digests: &[Digest],
        blooms: &[BloomFilter],
        cache: &ShardCache,
        shard_epochs: &[u64],
    ) -> Vec<usize> {
        if !self.cfg.adaptive {
            return (0..num_shards).collect();
        }
        let scores: Vec<u64> = (0..num_shards)
            .map(|s| self.score(s, selective_now, digests, blooms, cache))
            .collect();
        cache.set_priorities(&scores);
        // materialize residency once: sort_by_key re-evaluates its key per
        // comparison, and is_resident takes a slot lock each call
        let resident: Vec<bool> = (0..num_shards)
            .map(|s| cache.is_resident(s, shard_epochs[s]))
            .collect();
        let mut order: Vec<usize> = (0..num_shards).collect();
        // resident shards sort after all non-resident ones; within each
        // class, score descending then id ascending — fully deterministic
        order.sort_by_key(|&s| (resident[s], std::cmp::Reverse(scores[s]), s));
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Codec;
    use crate::graph::csr::Csr;
    use crate::storage::shardfile;

    fn adaptive(initial: usize, max: usize) -> Governor {
        Governor::new(GovernorConfig::from_engine(true, initial, max), 1000)
    }

    #[test]
    fn fixed_mode_never_moves() {
        let g = Governor::new(GovernorConfig::from_engine(false, 3, 8), 1000);
        assert_eq!(g.plan_window(None), 3);
        g.observe(1_000_000, 1); // 100% io-bound
        assert_eq!(g.plan_window(Some(0)), 3);
        assert_eq!(g.high_water(), 3);
        let cache = ShardCache::new(4, Codec::None, usize::MAX);
        let blooms: Vec<BloomFilter> = (0..4).map(|_| BloomFilter::new(64, 1)).collect();
        assert_eq!(g.schedule(4, false, &[], &blooms, &cache, &[0; 4]), vec![0, 1, 2, 3]);
    }

    fn digests(keys: &[u64]) -> Vec<crate::bloom::Digest> {
        keys.iter().map(|&k| crate::bloom::digest(k)).collect()
    }

    #[test]
    fn grows_when_io_bound_and_shrinks_when_compute_bound() {
        let g = adaptive(1, 8);
        // io-bound iterations: 1 -> 2 -> 4 -> 8, capped
        for want in [2usize, 4, 8, 8] {
            g.observe(900, 100);
            assert_eq!(g.plan_window(None), want);
        }
        assert_eq!(g.high_water(), 8);
        // compute-bound: additive decrease down to 1
        for want in [7usize, 6, 5] {
            g.observe(1, 999);
            assert_eq!(g.plan_window(None), want);
        }
        for _ in 0..20 {
            g.observe(0, 100);
        }
        assert_eq!(g.plan_window(None), 1, "floor at 1 keeps the pipeline alive");
        // mid-band fraction: hold steady
        g.observe(25, 75);
        assert_eq!(g.plan_window(None), 1);
    }

    #[test]
    fn cache_loan_caps_growth_and_is_reclaimed() {
        let g = Governor::new(GovernorConfig::from_engine(true, 2, 16), 1000);
        for _ in 0..4 {
            g.observe(900, 100); // wants 16
        }
        assert_eq!(g.depth(), 16);
        // empty finite cache lends 3 whole slots => base 2 + 3
        assert_eq!(g.plan_window(Some(3500)), 5);
        // cache fills, loan reclaimed down to the configured base
        assert_eq!(g.plan_window(Some(900)), 2);
        assert_eq!(g.plan_window(Some(0)), 2);
        // unbounded/disabled cache: only max_depth gates
        assert_eq!(g.plan_window(None), 16);
        assert_eq!(g.high_water(), 16);
    }

    #[test]
    fn schedule_puts_hot_uncached_first_and_resident_last() {
        let g = adaptive(2, 8);
        // 3 shards over intervals [0,8), [8,16), [16,24)
        let mut blooms: Vec<BloomFilter> = (0..3).map(|_| BloomFilter::new(256, 2)).collect();
        // shard 0: no active sources; shard 1: both; shard 2: one
        blooms[1].insert(100);
        blooms[1].insert(101);
        blooms[2].insert(100);
        let cache = ShardCache::new(3, Codec::None, usize::MAX);
        // make shard 0 cache-resident
        let edges: Vec<(u32, u32)> = (0..16).map(|i| (i % 4, i % 8)).collect();
        let payload = shardfile::to_bytes(&Csr::from_edges(0, 8, &edges));
        cache.insert(0, 0, &payload).unwrap();
        assert!(cache.is_resident(0, 0));

        let active = digests(&[100, 101]);
        let order = g.schedule(3, true, &active, &blooms, &cache, &[0; 3]);
        assert_eq!(order, vec![1, 2, 0], "densest uncached first, resident last");

        // determinism: identical inputs, identical order
        assert_eq!(order, g.schedule(3, true, &active, &blooms, &cache, &[0; 3]));
    }
}
