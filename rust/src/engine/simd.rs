//! Vectorized per-lane fold kernels for the gather inner loop.
//!
//! [`process_rows`](crate::engine::process_rows)'s scalar fold is a
//! serial dependency chain: one `acc = combine(acc, map(col[k]))` per
//! edge, so the CPU retires roughly one edge per combine latency.  These
//! kernels break the chain where the math allows it and keep it where it
//! doesn't, so results stay **bit-identical** to the scalar fold:
//!
//! * **Min/Max** — associative and commutative on every lane, so the run
//!   folds into [`LANES`] independent accumulators (which the
//!   autovectorizer turns into vector `min`/`max` ops and the OoO core
//!   can overlap regardless) and combines them in a fixed order.  Integer
//!   lanes are exact by construction; float lanes are exact for every
//!   value the engine produces (reassociation could only differ on
//!   `±0.0` ties or NaN, neither of which the app registry emits).
//! * **Sum, integer lanes** — wrapping add is exactly associative, so the
//!   same multi-accumulator shape applies
//!   ([`VertexValue::SUM_REASSOCIATES`]).
//! * **Sum, float lanes** — addition is order-sensitive, so the add chain
//!   stays strictly left-to-right; only the *map* half (the `src` gather,
//!   degree divide, weight lift) is blocked through a scratch array where
//!   it vectorizes and pipelines independently of the serial adds.
//!
//! The kernels are written against the safe portable subset (chunked
//! slices + fixed-size arrays) rather than `std::arch` intrinsics: the
//! shapes below are exactly what LLVM's vectorizer recognizes, and one
//! source path means the runtime `--simd`/`--no-simd` toggle selects
//! *dispatch* (runs vs per-edge callbacks), not a second numeric
//! implementation.  [`level`] reports what the host actually runs.

use std::sync::OnceLock;

use crate::apps::VertexValue;

/// Independent accumulators for reassociable reductions; 8 × 64-bit
/// covers one AVX-512 register or two NEON/SSE registers.
pub const LANES: usize = 8;

/// Map-block size for the order-preserving float-sum path.
const BLOCK: usize = 32;

/// Runtime default for [`crate::engine::EngineConfig::simd`]:
/// `GRAPHMP_SIMD=0` disables, anything else (or unset) enables.
pub fn enabled_default() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var("GRAPHMP_SIMD").map(|v| v != "0").unwrap_or(true))
}

/// Best vector ISA the autovectorized kernels can use on this host
/// (reporting only — dispatch is portable).
pub fn level() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
        if std::arch::is_x86_feature_detected!("sse4.1") {
            return "sse4.1";
        }
        return "sse2";
    }
    #[cfg(target_arch = "aarch64")]
    return "neon";
    #[allow(unreachable_code)]
    "portable"
}

/// `min(map(u) for u in cols)` with `vmax_value` identity.
#[inline]
pub fn min_map<V: VertexValue, F: Fn(u32) -> V>(cols: &[u32], map: F) -> V {
    let mut accs = [V::vmax_value(); LANES];
    let mut it = cols.chunks_exact(LANES);
    for chunk in it.by_ref() {
        for (a, &u) in accs.iter_mut().zip(chunk) {
            *a = a.vmin(map(u));
        }
    }
    let mut acc = accs[0];
    for &a in &accs[1..] {
        acc = acc.vmin(a);
    }
    for &u in it.remainder() {
        acc = acc.vmin(map(u));
    }
    acc
}

/// `max(map(u) for u in cols)` with `vmin_value` identity.
#[inline]
pub fn max_map<V: VertexValue, F: Fn(u32) -> V>(cols: &[u32], map: F) -> V {
    let mut accs = [V::vmin_value(); LANES];
    let mut it = cols.chunks_exact(LANES);
    for chunk in it.by_ref() {
        for (a, &u) in accs.iter_mut().zip(chunk) {
            *a = a.vmax(map(u));
        }
    }
    let mut acc = accs[0];
    for &a in &accs[1..] {
        acc = acc.vmax(a);
    }
    for &u in it.remainder() {
        acc = acc.vmax(map(u));
    }
    acc
}

/// `min(map(u, w))` over an edge run with a parallel weight lane.
#[inline]
pub fn min_zip<V: VertexValue, F: Fn(u32, f32) -> V>(cols: &[u32], wgts: &[f32], map: F) -> V {
    debug_assert_eq!(cols.len(), wgts.len());
    let mut accs = [V::vmax_value(); LANES];
    let mut cit = cols.chunks_exact(LANES);
    let mut wit = wgts.chunks_exact(LANES);
    for (cc, wc) in cit.by_ref().zip(wit.by_ref()) {
        for ((a, &u), &w) in accs.iter_mut().zip(cc).zip(wc) {
            *a = a.vmin(map(u, w));
        }
    }
    let mut acc = accs[0];
    for &a in &accs[1..] {
        acc = acc.vmin(a);
    }
    for (&u, &w) in cit.remainder().iter().zip(wit.remainder()) {
        acc = acc.vmin(map(u, w));
    }
    acc
}

/// `max(map(u, w))` over an edge run with a parallel weight lane.
#[inline]
pub fn max_zip<V: VertexValue, F: Fn(u32, f32) -> V>(cols: &[u32], wgts: &[f32], map: F) -> V {
    debug_assert_eq!(cols.len(), wgts.len());
    let mut accs = [V::vmin_value(); LANES];
    let mut cit = cols.chunks_exact(LANES);
    let mut wit = wgts.chunks_exact(LANES);
    for (cc, wc) in cit.by_ref().zip(wit.by_ref()) {
        for ((a, &u), &w) in accs.iter_mut().zip(cc).zip(wc) {
            *a = a.vmax(map(u, w));
        }
    }
    let mut acc = accs[0];
    for &a in &accs[1..] {
        acc = acc.vmax(a);
    }
    for (&u, &w) in cit.remainder().iter().zip(wit.remainder()) {
        acc = acc.vmax(map(u, w));
    }
    acc
}

/// `sum(map(u, w))` over an edge run with a parallel weight lane, under
/// the same bit-identity discipline as [`sum_map`]: integer lanes
/// reassociate across [`LANES`] accumulators, float lanes keep the serial
/// add order and only block the (gather × weight) map.
#[inline]
pub fn sum_zip<V: VertexValue, F: Fn(u32, f32) -> V>(cols: &[u32], wgts: &[f32], map: F) -> V {
    debug_assert_eq!(cols.len(), wgts.len());
    if V::SUM_REASSOCIATES {
        let mut accs = [V::vzero(); LANES];
        let mut cit = cols.chunks_exact(LANES);
        let mut wit = wgts.chunks_exact(LANES);
        for (cc, wc) in cit.by_ref().zip(wit.by_ref()) {
            for ((a, &u), &w) in accs.iter_mut().zip(cc).zip(wc) {
                *a = a.vadd(map(u, w));
            }
        }
        let mut acc = accs[0];
        for &a in &accs[1..] {
            acc = acc.vadd(a);
        }
        for (&u, &w) in cit.remainder().iter().zip(wit.remainder()) {
            acc = acc.vadd(map(u, w));
        }
        return acc;
    }
    let mut acc = V::vzero();
    let mut scratch = [V::vzero(); BLOCK];
    let mut cit = cols.chunks_exact(BLOCK);
    let mut wit = wgts.chunks_exact(BLOCK);
    for (cc, wc) in cit.by_ref().zip(wit.by_ref()) {
        // the map half (gathers, weight lifts) vectorizes here...
        for ((s, &u), &w) in scratch.iter_mut().zip(cc).zip(wc) {
            *s = map(u, w);
        }
        // ...while the adds keep the exact scalar order
        for &s in &scratch {
            acc = acc.vadd(s);
        }
    }
    for (&u, &w) in cit.remainder().iter().zip(wit.remainder()) {
        acc = acc.vadd(map(u, w));
    }
    acc
}

/// `sum(map(u) for u in cols)` from `vzero`, bit-identical to the scalar
/// left fold: integer lanes reassociate across [`LANES`] accumulators
/// (exact), float lanes keep the serial add order and only block the map.
#[inline]
pub fn sum_map<V: VertexValue, F: Fn(u32) -> V>(cols: &[u32], map: F) -> V {
    if V::SUM_REASSOCIATES {
        let mut accs = [V::vzero(); LANES];
        let mut it = cols.chunks_exact(LANES);
        for chunk in it.by_ref() {
            for (a, &u) in accs.iter_mut().zip(chunk) {
                *a = a.vadd(map(u));
            }
        }
        let mut acc = accs[0];
        for &a in &accs[1..] {
            acc = acc.vadd(a);
        }
        for &u in it.remainder() {
            acc = acc.vadd(map(u));
        }
        return acc;
    }
    let mut acc = V::vzero();
    let mut scratch = [V::vzero(); BLOCK];
    let mut it = cols.chunks_exact(BLOCK);
    for chunk in it.by_ref() {
        // the map half (gathers, divides) vectorizes here...
        for (s, &u) in scratch.iter_mut().zip(chunk) {
            *s = map(u);
        }
        // ...while the adds keep the exact scalar order
        for &s in &scratch {
            acc = acc.vadd(s);
        }
    }
    for &u in it.remainder() {
        acc = acc.vadd(map(u));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_min<V: VertexValue>(cols: &[u32], map: impl Fn(u32) -> V) -> V {
        cols.iter().fold(V::vmax_value(), |a, &u| a.vmin(map(u)))
    }

    fn scalar_max<V: VertexValue>(cols: &[u32], map: impl Fn(u32) -> V) -> V {
        cols.iter().fold(V::vmin_value(), |a, &u| a.vmax(map(u)))
    }

    fn scalar_sum<V: VertexValue>(cols: &[u32], map: impl Fn(u32) -> V) -> V {
        cols.iter().fold(V::vzero(), |a, &u| a.vadd(map(u)))
    }

    #[test]
    fn kernels_match_scalar_folds_at_every_length() {
        // lengths straddle the chunk boundaries (LANES=8, BLOCK=32)
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(42);
        for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 63, 257] {
            let cols: Vec<u32> = (0..len).map(|_| rng.gen_range(1000) as u32).collect();
            let wgts: Vec<f32> = (0..len).map(|_| rng.next_f32() + 0.01).collect();
            let src: Vec<f32> = (0..1000).map(|i| (i as f32) * 0.37 + 0.5).collect();
            let src64: Vec<u64> = (0..1000).collect();

            let m = |u: u32| src[u as usize];
            assert_eq!(min_map(&cols, m).to_bits(), scalar_min(&cols, m).to_bits(), "min {len}");
            assert_eq!(max_map(&cols, m).to_bits(), scalar_max(&cols, m).to_bits(), "max {len}");
            // float sum: strict order must survive the blocking
            assert_eq!(sum_map(&cols, m).to_bits(), scalar_sum(&cols, m).to_bits(), "sum {len}");
            // integer sum: multi-accumulator reassociation is exact
            let mi = |u: u32| src64[u as usize];
            assert_eq!(sum_map(&cols, mi), scalar_sum(&cols, mi), "u64 sum {len}");

            let mz = |u: u32, w: f32| src[u as usize] + w;
            let want = cols
                .iter()
                .zip(&wgts)
                .fold(f32::vmax_value(), |a, (&u, &w)| a.vmin(mz(u, w)));
            assert_eq!(min_zip(&cols, &wgts, mz).to_bits(), want.to_bits(), "zip {len}");

            // weighted max: same multi-accumulator shape as min_zip
            let want = cols
                .iter()
                .zip(&wgts)
                .fold(f32::vmin_value(), |a, (&u, &w)| a.vmax(mz(u, w)));
            assert_eq!(max_zip(&cols, &wgts, mz).to_bits(), want.to_bits(), "max zip {len}");
            // weighted float sum: strict order must survive the blocking
            let want = cols.iter().zip(&wgts).fold(0.0f32, |a, (&u, &w)| a.vadd(mz(u, w)));
            assert_eq!(sum_zip(&cols, &wgts, mz).to_bits(), want.to_bits(), "sum zip {len}");
            // weighted integer sum: reassociation is exact (weights lift to 1)
            let mzi = |u: u32, w: f32| src64[u as usize].wrapping_add(w as u64);
            let want = cols.iter().zip(&wgts).fold(0u64, |a, (&u, &w)| a.vadd(mzi(u, w)));
            assert_eq!(sum_zip(&cols, &wgts, mzi), want, "u64 sum zip {len}");
        }
    }

    #[test]
    fn identities_on_empty_runs() {
        let m = |u: u32| u as f32;
        assert_eq!(min_map::<f32, _>(&[], m), f32::vmax_value());
        assert_eq!(max_map::<f32, _>(&[], m), f32::vmin_value());
        assert_eq!(sum_map::<f32, _>(&[], m), 0.0);
        let mz = |u: u32, w: f32| u as f32 + w;
        assert_eq!(min_zip::<f32, _>(&[], &[], mz), f32::vmax_value());
        assert_eq!(max_zip::<f32, _>(&[], &[], mz), f32::vmin_value());
        assert_eq!(sum_zip::<f32, _>(&[], &[], mz), 0.0);
        assert!(!level().is_empty());
    }

    #[test]
    fn infinities_survive_min_lanes() {
        // SSSP-style runs: mostly +inf with a few finite distances
        let cols: Vec<u32> = (0..50).collect();
        let src: Vec<f32> = (0..50)
            .map(|i| if i % 9 == 0 { i as f32 } else { f32::INFINITY })
            .collect();
        let m = |u: u32| src[u as usize];
        assert_eq!(min_map(&cols, m).to_bits(), scalar_min(&cols, m).to_bits());
    }
}
