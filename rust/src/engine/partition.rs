//! Range-restricted VSW execution + the barrier delta codec — the engine
//! half of partitioned execution (`graphmp partrun`, [`crate::cluster`]).
//!
//! ## Why a partitioned step is bit-identical by construction
//!
//! Shards partition edges by *destination* interval: every in-edge of a
//! destination vertex lives in exactly one shard (plus that shard's
//! resident delta).  The per-destination fold is a pure function of (the
//! shard's rows in their fixed on-disk order, the full `src` array), and
//! [`step_shards`] runs it through the very same
//! [`fold_chunk`](crate::engine::vsw) / `process_rows` / SIMD kernels the
//! single-process loop uses — so a worker that owns a shard computes the
//! exact bits the single-process engine would, regardless of which worker
//! owns it or how many workers there are.  The only thing partitioning
//! changes is *which process* holds a destination range; the values
//! flowing between processes are re-synchronized at iteration barriers
//! via [`encode_delta`] lines.
//!
//! ## The delta codec
//!
//! One line per bit-changed own-range vertex, `"{v} {bits} {flag}"`:
//! `bits` is [`AnyValues::render_bits`]'s exact per-lane text form
//! (integer lanes decimal, float lanes IEEE bit patterns in hex — the
//! `--dump-values` format, so dumps stay byte-comparable end to end), and
//! `flag` is `1` iff the vertex is *active* under the engine's tolerance
//! predicate ([`VertexValue::changed`]).  Active ⊆ bit-changed on every
//! lane for any `tol ≥ 0` (a value that moved beyond the tolerance cannot
//! have kept its bits), so a single line set carries both the value sync
//! and the frontier bits.  The change scan itself is the bit-pattern diff
//! the standing-query layer established
//! ([`crate::engine::standing::diff_changed`]), applied range-restricted
//! while the fold's output is still hot.

use anyhow::{Context, Result};

use crate::apps::{ProgramContext, VertexProgram, VertexValue};
use crate::bloom::Digest;
use crate::engine::backend::CsrRows;
use crate::engine::vsw::{fold_chunk, EpochState, VswEngine};
use crate::graph::value::Lane;
use crate::graph::VertexId;
use crate::storage::io;

/// What one worker's iteration step produced over its owned shards.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Own-range vertices active under the tolerance predicate, ascending
    /// (the worker's contribution to the next global frontier).
    pub active: Vec<VertexId>,
    /// One [`encode_delta`] line per bit-changed own-range vertex,
    /// ascending — the barrier payload other workers apply.
    pub lines: Vec<String>,
    pub shards_processed: usize,
    pub shards_skipped: usize,
    /// Edges folded (resident deltas included), for iteration stats.
    pub edges: u64,
}

/// One partitioned iteration over `shards` (the worker's owned contiguous
/// shard run): Bloom-screen exactly like the single-process loop, fold
/// each surviving shard *whole* through the shared [`fold_chunk`] into
/// `next[interval]`, carry screened intervals forward from `cur`, then
/// scan the owned ranges for bit changes and tolerance-actives.
///
/// `cur` must be the globally-consistent value array entering this
/// iteration (all ranges synced); only `next`'s owned intervals are
/// written.  `selective_now` and `digests` must be derived from the
/// *global* frontier (the coordinator's merged active count and the
/// worker's merged frontier) so every worker makes the same screening
/// decision the single-process engine would.
#[allow(clippy::too_many_arguments)]
pub fn step_shards<V: VertexValue, P: VertexProgram<V> + ?Sized>(
    engine: &VswEngine,
    st: &EpochState,
    app: &P,
    shards: &[usize],
    selective_now: bool,
    digests: &[Digest],
    cur: &[V],
    next: &mut [V],
) -> Result<StepOutcome> {
    let cfg = engine.config();
    let n = st.property.info.num_vertices as usize;
    anyhow::ensure!(
        cur.len() == n && next.len() == n,
        "value arrays cover {}/{} vertices, dataset has {n}",
        cur.len(),
        next.len()
    );
    let p = st.property.num_shards();
    let ctx = ProgramContext { num_vertices: n as u64 };
    let out_deg = &st.vertex_info.degrees.out_deg;
    let mut outcome = StepOutcome::default();

    for &shard in shards {
        anyhow::ensure!(shard < p, "owned shard {shard} out of range (dataset has {p})");
        let (lo, hi) = st.property.interval(shard);
        let (lo, hi) = (lo as usize, hi as usize);
        if selective_now && !st.blooms[shard].contains_any_digest(digests) {
            // line 5: provably inactive — carry the interval forward
            next[lo..hi].copy_from_slice(&cur[lo..hi]);
            outcome.shards_skipped += 1;
            continue;
        }
        let admit = cfg.cache_budget > 0;
        let read = || match engine.direct_reader() {
            Some(r) => r.read_file(&st.shard_paths[shard]),
            None => io::read_file(&st.shard_paths[shard]),
        };
        let csr = engine.cache().fetch_decoded(shard, st.shard_epochs[shard], admit, read)?;
        anyhow::ensure!(
            csr.lo as usize == lo && csr.num_vertices() == hi - lo,
            "shard {shard} interval disagrees with property"
        );
        let delta = st.deltas[shard].as_deref();
        let rows = csr.num_vertices();
        fold_chunk(
            app,
            CsrRows::new(&csr, 0..rows),
            delta,
            0,
            cur,
            out_deg,
            &ctx,
            cfg.simd,
            &mut next[lo..hi],
        )?;
        outcome.edges += match delta {
            Some(d) => d.effective_edges(csr.num_edges() as u64),
            None => csr.num_edges() as u64,
        };
        outcome.shards_processed += 1;
    }

    // the range-restricted bit diff + active scan (standing's diff with
    // the frontier flag folded into the same pass)
    let tol = cfg.convergence_tol as f64;
    let (mut ba, mut bb) = (Vec::with_capacity(8), Vec::with_capacity(8));
    for &shard in shards {
        let (lo, hi) = st.property.interval(shard);
        for v in lo..hi {
            let i = v as usize;
            let (old, new) = (cur[i], next[i]);
            ba.clear();
            bb.clear();
            old.write_le(&mut ba);
            new.write_le(&mut bb);
            let is_active = V::changed(old, new, tol);
            if ba != bb || is_active {
                outcome.lines.push(encode_delta(v, new, is_active));
                if is_active {
                    outcome.active.push(v);
                }
            }
        }
    }
    Ok(outcome)
}

/// Bit-exact text form of one value — [`AnyValues::render_bits`]'s
/// per-lane rendering (integer decimal, float IEEE bits in hex), typed.
///
/// [`AnyValues::render_bits`]: crate::graph::AnyValues::render_bits
pub fn render_value<V: VertexValue>(v: V) -> String {
    let mut b = Vec::with_capacity(V::BYTES);
    v.write_le(&mut b);
    match V::LANE {
        Lane::U32 => u32::from_le_bytes(b[..4].try_into().unwrap()).to_string(),
        Lane::U64 => u64::from_le_bytes(b[..8].try_into().unwrap()).to_string(),
        Lane::F32 => format!("{:08x}", u32::from_le_bytes(b[..4].try_into().unwrap())),
        Lane::F64 => format!("{:016x}", u64::from_le_bytes(b[..8].try_into().unwrap())),
    }
}

/// Invert [`render_value`].
pub fn parse_value<V: VertexValue>(s: &str) -> Result<V> {
    let err = || format!("bad {} value {s:?}", V::LANE.name());
    Ok(match V::LANE {
        Lane::U32 => {
            let x: u32 = s.parse().with_context(err)?;
            V::read_le(&x.to_le_bytes())
        }
        Lane::U64 => {
            let x: u64 = s.parse().with_context(err)?;
            V::read_le(&x.to_le_bytes())
        }
        Lane::F32 => {
            let x = u32::from_str_radix(s, 16).with_context(err)?;
            V::read_le(&x.to_le_bytes())
        }
        Lane::F64 => {
            let x = u64::from_str_radix(s, 16).with_context(err)?;
            V::read_le(&x.to_le_bytes())
        }
    })
}

/// One barrier line: `"{v} {bits} {flag}"`, `flag = 1` iff active.
pub fn encode_delta<V: VertexValue>(v: VertexId, val: V, active: bool) -> String {
    format!("{v} {} {}", render_value(val), active as u8)
}

/// Invert [`encode_delta`].
pub fn decode_delta<V: VertexValue>(line: &str) -> Result<(VertexId, V, bool)> {
    let mut it = line.split_ascii_whitespace();
    let (v, bits, flag) = (it.next(), it.next(), it.next());
    let (Some(v), Some(bits), Some(flag), None) = (v, bits, flag, it.next()) else {
        anyhow::bail!("malformed delta line {line:?} (want \"v bits flag\")");
    };
    let v: VertexId = v.parse().with_context(|| format!("bad vertex id in {line:?}"))?;
    let val = parse_value::<V>(bits)?;
    let active = match flag {
        "0" => false,
        "1" => true,
        other => anyhow::bail!("bad active flag {other:?} in delta line"),
    };
    Ok((v, val, active))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_text_roundtrips_bitwise_on_every_lane() {
        fn rt<V: VertexValue>(x: V) {
            let s = render_value(x);
            let back: V = parse_value(&s).unwrap();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            x.write_le(&mut a);
            back.write_le(&mut b);
            assert_eq!(a, b, "{s}");
        }
        rt(0u32);
        rt(u32::MAX);
        rt(u64::MAX - 7);
        rt(-0.0f32);
        rt(f32::INFINITY);
        rt(1.5f32);
        rt(f64::NEG_INFINITY);
        rt(std::f64::consts::PI);
    }

    #[test]
    fn rendering_matches_anyvalues_render_bits() {
        use crate::graph::AnyValues;
        assert_eq!(
            render_value(1.5f32),
            AnyValues::F32(vec![1.5]).render_bits(0).unwrap()
        );
        assert_eq!(
            render_value(2.5f64),
            AnyValues::F64(vec![2.5]).render_bits(0).unwrap()
        );
        assert_eq!(render_value(7u32), AnyValues::U32(vec![7]).render_bits(0).unwrap());
        assert_eq!(
            render_value(u64::MAX),
            AnyValues::U64(vec![u64::MAX]).render_bits(0).unwrap()
        );
    }

    #[test]
    fn delta_lines_roundtrip_and_reject_garbage() {
        let line = encode_delta(42u32, f32::INFINITY, true);
        let (v, val, active) = decode_delta::<f32>(&line).unwrap();
        assert_eq!((v, active), (42, true));
        assert_eq!(val.to_bits(), f32::INFINITY.to_bits());

        let line = encode_delta(7u32, 9u64, false);
        assert_eq!(decode_delta::<u64>(&line).unwrap(), (7, 9, false));

        assert!(decode_delta::<f32>("42").is_err());
        assert!(decode_delta::<f32>("42 3f800000 2").is_err());
        assert!(decode_delta::<f32>("x 3f800000 1").is_err());
        assert!(decode_delta::<f32>("42 zz 1").is_err());
        assert!(decode_delta::<f32>("42 3f800000 1 extra").is_err());
        // integer lanes parse decimal, not hex
        assert!(decode_delta::<u32>("42 zz 1").is_err());
    }
}
