//! Standing queries over the mutation stream (`graphmp watch`).
//!
//! A standing query keeps an app's fixpoint alive across ingests and, on
//! each advance, re-emits **only the vertices whose bit-exact value
//! changed** since the previous emission.  The state lives in a `GMCS`
//! sidecar next to the dataset ([`DatasetDir::watch_path`]): the baseline
//! value vector, the epoch it was computed at, the last changed-set, and —
//! for `--window N` queries — the sliding-window membership.
//!
//! The same decision tree also backs `run --incremental`
//! ([`incremental_run`]), so the CLI one-shot, the daemon `watch`/`poll`
//! verbs and the restart path all share one implementation:
//!
//! * **monotone apps** (Min/Max reduce) — [`mutation::incremental_plan`]
//!   derives a warm-start seed; delete-bearing ranges additionally carry a
//!   reset set (the forward closure of deleted-edge destinations) that
//!   [`VswEngine::run_any_plan`] re-initialises before relaxing.  Only when
//!   a batch in the range is unreplayable does the query fall back cold.
//! * **single-pass Sum apps** with a degree-oblivious gather (Identity /
//!   PlusOne / PlusWeight, effective `max_iters == 1`) — every row is an
//!   independent fold over its in-edges, so only mutation destinations can
//!   change.  [`VswEngine::run_any_rows`] refolds exactly those rows
//!   through the same kernels the cold pass uses, which keeps the result
//!   bit-identical to a cold recompute.
//! * **everything else** (iterative Sum like PageRank) — recompute cold;
//!   the changed-set diff still applies.
//!
//! ## Sliding windows
//!
//! `--window N` interprets the query as "the fixpoint over the last `N`
//! ingest batches".  Aging a batch out is just more mutation stream: the
//! archived batch's inserts are replayed as deletes (its own deletes are
//! dropped — a tombstone already kills every `(src,dst)` occurrence, which
//! is the system-wide delete semantics the window inherits).  The expiry
//! ingest happens *before* the advance, so one warm/rows pass absorbs both
//! the payload and the expiry.  A pruned archived batch is dropped from
//! the window with a warning rather than failing the query.  One windowed
//! watch per dataset is supported: a second windowed watch would observe
//! the first one's expiry batches as payload.

use anyhow::{Context, Result};

use crate::apps::{AnyProgram, GatherKind, Reduce};
use crate::engine::{AnyRunResult, VswEngine};
use crate::graph::mutation::{self, Mutation};
use crate::graph::{AnyValues, VertexId};
use crate::runtime::EpochManifest;
use crate::storage::delta::{self, WatchState};
use crate::storage::property::Property;
use crate::storage::DatasetDir;

/// How an advance obtained its new values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdvanceMode {
    /// Full recompute from `init` (first emission or fallback).
    Cold,
    /// Monotone warm restart seeded from the mutation range.
    Warm,
    /// Monotone warm restart with delete-derived resets.
    WarmReset,
    /// Single-pass Sum row maintenance (mutation destinations only).
    Rows,
    /// Nothing to do — the baseline epoch is already current.
    Idle,
}

impl AdvanceMode {
    pub fn as_str(self) -> &'static str {
        match self {
            AdvanceMode::Cold => "cold",
            AdvanceMode::Warm => "warm",
            AdvanceMode::WarmReset => "warm+reset",
            AdvanceMode::Rows => "rows",
            AdvanceMode::Idle => "idle",
        }
    }
}

/// Result of advancing a value vector from one epoch to the engine's.
pub struct Advance {
    pub result: AnyRunResult,
    pub mode: AdvanceMode,
}

/// One `watch` emission: the epoch it brings the query to and the
/// changed-set lines (`<vertex> <bits>`, ascending by vertex).
pub struct WatchOutcome {
    pub epoch: u64,
    pub mode: AdvanceMode,
    /// True when this call created the sidecar (full emission).
    pub registered: bool,
    /// Ingest batches aged out of the sliding window by this advance.
    pub expired: usize,
    pub lines: Vec<String>,
    pub stats: crate::engine::RunStats,
}

/// Effective iteration bound: the engine config wins when set, the app
/// default otherwise (mirrors the run loop's own resolution).
fn effective_max_iters(engine: &VswEngine, app: &AnyProgram) -> usize {
    let cfg = engine.config().max_iters;
    if cfg > 0 {
        cfg
    } else {
        app.default_max_iters()
    }
}

/// Is `app` a single-pass Sum whose gather never reads vertex degrees?
/// Those rows are independent folds, so row-level maintenance is exact.
fn sum_single_pass(engine: &VswEngine, app: &AnyProgram) -> bool {
    app.reduce() == Reduce::Sum
        && effective_max_iters(engine, app) == 1
        && matches!(
            app.gather_kind(),
            GatherKind::Identity | GatherKind::PlusOne | GatherKind::PlusWeight
        )
}

/// Destinations touched by the mutation range `(from, to]`, or `None`
/// when a batch in the range is missing/unarchived (degrade cold).
fn affected_rows(
    dir: &DatasetDir,
    manifest: &EpochManifest,
    from: u64,
    to: u64,
) -> Result<Option<Vec<VertexId>>> {
    let mut rows: Vec<VertexId> = Vec::new();
    for e in manifest.epochs_between(from, to) {
        if e.kind == "compact" {
            continue;
        }
        let Some(b) = &e.batch else { return Ok(None) };
        let path = dir.root.join(b);
        if !path.exists() {
            return Ok(None);
        }
        for m in delta::load_log(&path)? {
            rows.push(m.dst());
        }
    }
    rows.sort_unstable();
    rows.dedup();
    Ok(Some(rows))
}

/// Advance `baseline` (computed at epoch `from`) to the engine's current
/// epoch along the cheapest exact path for `app`.  `from` must not be
/// ahead of the engine — callers that can see a future baseline (stale
/// saved fixpoints) must check and fall back cold themselves.
pub fn advance_values(
    dir: &DatasetDir,
    engine: &VswEngine,
    app: &AnyProgram,
    baseline: AnyValues,
    from: u64,
) -> Result<Advance> {
    let to = engine.epoch();
    anyhow::ensure!(
        from <= to,
        "baseline epoch {from} is ahead of engine epoch {to}"
    );
    if from == to {
        return Ok(Advance {
            result: AnyRunResult { values: baseline, stats: Default::default() },
            mode: AdvanceMode::Idle,
        });
    }
    let property = Property::load(&dir.property_path()).context("property")?;
    let manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
    if app.reduce().is_monotone() {
        if let Some(plan) = mutation::incremental_plan(dir, &manifest, from, to)? {
            let mode =
                if plan.has_resets() { AdvanceMode::WarmReset } else { AdvanceMode::Warm };
            return Ok(Advance { result: engine.run_any_plan(app, baseline, &plan)?, mode });
        }
    } else if sum_single_pass(engine, app) {
        if let Some(rows) = affected_rows(dir, &manifest, from, to)? {
            return Ok(Advance {
                result: engine.run_any_rows(app, baseline, &rows)?,
                mode: AdvanceMode::Rows,
            });
        }
    }
    Ok(Advance { result: engine.run_any(app)?, mode: AdvanceMode::Cold })
}

/// `run --incremental`: resume from the saved fixpoint
/// (`DatasetDir::values_path`) when it is usable, cold otherwise.  A
/// fixpoint saved at a *later* epoch than the run target must not warm-
/// start — `epochs_between` would see an empty range and silently keep
/// future values — so it degrades cold with an explanation.
pub fn incremental_run(
    dir: &DatasetDir,
    engine: &VswEngine,
    app: &AnyProgram,
) -> Result<Advance> {
    let path = dir.values_path(app.name());
    let (saved_epoch, values) = delta::load_values(&path)
        .with_context(|| format!("loading saved values {}", path.display()))?;
    let to = engine.epoch();
    if saved_epoch > to {
        eprintln!(
            "incremental: saved fixpoint for {} is at epoch {saved_epoch}, ahead of run \
             epoch {to}; recomputing cold",
            app.name()
        );
        return Ok(Advance { result: engine.run_any(app)?, mode: AdvanceMode::Cold });
    }
    advance_values(dir, engine, app, values, saved_epoch)
}

/// Bitwise inequality diff of two same-lane value vectors: the vertices
/// whose stored bits differ, ascending.  Float lanes compare IEEE bit
/// patterns (so `-0.0 != 0.0` and NaN payloads count), matching the
/// `--dump-values` text diff line for line.
pub fn diff_changed(old: &AnyValues, new: &AnyValues) -> Result<Vec<VertexId>> {
    anyhow::ensure!(
        old.lane() == new.lane() && old.len() == new.len(),
        "changed-set diff needs matching vectors ({} x{} vs {} x{})",
        old.lane().name(),
        old.len(),
        new.lane().name(),
        new.len()
    );
    let mut out = Vec::new();
    macro_rules! scan {
        ($a:expr, $b:expr, $ne:expr) => {
            for (i, (x, y)) in $a.iter().zip($b.iter()).enumerate() {
                if $ne(*x, *y) {
                    out.push(i as VertexId);
                }
            }
        };
    }
    match (old, new) {
        (AnyValues::U32(a), AnyValues::U32(b)) => scan!(a, b, |x: u32, y: u32| x != y),
        (AnyValues::U64(a), AnyValues::U64(b)) => scan!(a, b, |x: u64, y: u64| x != y),
        (AnyValues::F32(a), AnyValues::F32(b)) => {
            scan!(a, b, |x: f32, y: f32| x.to_bits() != y.to_bits())
        }
        (AnyValues::F64(a), AnyValues::F64(b)) => {
            scan!(a, b, |x: f64, y: f64| x.to_bits() != y.to_bits())
        }
        _ => unreachable!("lane equality checked above"),
    }
    Ok(out)
}

fn changed_lines(values: &AnyValues, changed: &[VertexId]) -> Vec<String> {
    changed
        .iter()
        .map(|&v| {
            let bits = values.render_bits(v as usize).expect("changed vertex within range");
            format!("{v} {bits}")
        })
        .collect()
}

/// Register-or-advance a standing query.
///
/// First call (no sidecar): computes the fixpoint cold, emits **every**
/// vertex, and writes the sidecar.  Subsequent calls: age out expired
/// window batches (ingesting their inserts as deletes), advance the
/// baseline along the cheapest exact path, emit only the changed lines,
/// and re-stamp the sidecar.  `window` overrides the stored window size
/// when `Some`; `None` keeps whatever the registration chose.
pub fn watch_advance(
    dir: &DatasetDir,
    engine: &VswEngine,
    app: &AnyProgram,
    window: Option<u32>,
) -> Result<WatchOutcome> {
    let path = dir.watch_path(app.name());
    if !path.exists() {
        let result = engine.run_any(app)?;
        let changed: Vec<VertexId> = (0..result.values.len() as VertexId).collect();
        let lines = changed_lines(&result.values, &changed);
        let state = WatchState {
            epoch: engine.epoch(),
            window: window.unwrap_or(0),
            window_epochs: Vec::new(),
            last_changed: changed,
            values: result.values,
        };
        delta::save_watch(&path, &state)?;
        return Ok(WatchOutcome {
            epoch: state.epoch,
            mode: AdvanceMode::Cold,
            registered: true,
            expired: 0,
            lines,
            stats: result.stats,
        });
    }

    let mut state = delta::load_watch(&path)
        .with_context(|| format!("loading watch state {}", path.display()))?;
    if let Some(w) = window {
        state.window = w;
    }

    let mut expired = 0usize;
    if state.window > 0 {
        let property = Property::load(&dir.property_path()).context("property")?;
        let manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
        for e in manifest.epochs_between(state.epoch, manifest.current) {
            if e.kind == "ingest" {
                state.window_epochs.push(e.id);
            }
        }
        while state.window_epochs.len() > state.window as usize {
            let old = state.window_epochs.remove(0);
            let Some(batch) = manifest.epoch(old).ok().and_then(|e| e.batch.clone()) else {
                eprintln!("watch: epoch {old} has no archived batch; dropping it from the window");
                expired += 1;
                continue;
            };
            let batch = dir.root.join(batch);
            if !batch.exists() {
                eprintln!(
                    "watch: archived batch for epoch {old} was pruned; dropping it from the window"
                );
                expired += 1;
                continue;
            }
            let tombs: Vec<Mutation> = delta::load_log(&batch)?
                .into_iter()
                .filter_map(|m| match m {
                    Mutation::Insert { src, dst, .. } => Some(Mutation::Delete { src, dst }),
                    Mutation::Delete { .. } => None,
                })
                .collect();
            if !tombs.is_empty() {
                mutation::ingest(dir, &tombs, 0.01)
                    .with_context(|| format!("expiring window epoch {old}"))?;
            }
            expired += 1;
        }
        if expired > 0 {
            engine.refresh_latest()?;
        }
    }

    let baseline = std::mem::take(&mut state.values);
    let adv = advance_values(dir, engine, app, baseline.clone(), state.epoch)?;
    let changed = diff_changed(&baseline, &adv.result.values)?;
    let lines = changed_lines(&adv.result.values, &changed);
    state.epoch = engine.epoch();
    state.last_changed = changed;
    state.values = adv.result.values;
    delta::save_watch(&path, &state)?;
    Ok(WatchOutcome {
        epoch: state.epoch,
        mode: adv.mode,
        registered: false,
        expired,
        lines,
        stats: adv.result.stats,
    })
}
