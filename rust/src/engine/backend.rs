//! Compute backends for the per-shard update.
//!
//! * [`Backend::Native`] — pure-rust segmented reduce+apply; the fast path
//!   used by paper-scale benches.  Generic over the vertex-value lane.
//! * [`Backend::Xla`] — the three-layer path: gather in rust, reduce+apply
//!   in the AOT-compiled Pallas/JAX artifact via PJRT.  Artifacts exist for
//!   the `f32` lane only; typed programs (`u32`/`u64`/`f64` lanes, or
//!   `KernelKind::None`) fall back to the native loop so every app runs on
//!   either backend.
//!
//! Both produce identical results (`tests/engine_equivalence.rs`).

use std::any::TypeId;
use std::sync::Arc;

use anyhow::Result;

use crate::apps::{GatherKind, KernelKind, ProgramContext, Reduce, VertexProgram, VertexValue};
use crate::cache::deltavarint::DvCursor;
use crate::engine::simd;
use crate::graph::csr::Csr;
use crate::graph::{VertexId, Weight};
use crate::runtime::ShardRuntime;
use crate::storage::shardfile::PayloadView;

/// Pluggable shard-update executor.
#[derive(Clone)]
pub enum Backend {
    Native,
    Xla(Arc<ShardRuntime>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Backend::Native"),
            Backend::Xla(_) => write!(f, "Backend::Xla"),
        }
    }
}

/// Reinterpret a slice as the same POD lane under a `TypeId` proof.
/// Returns `None` when `A` and `B` differ, so the cast is total and safe
/// to call speculatively.
fn same_lane_slice<A: 'static, B: 'static>(s: &[A]) -> Option<&[B]> {
    if TypeId::of::<A>() == TypeId::of::<B>() {
        // SAFETY: A and B are the very same type (TypeId equality above),
        // so layout, alignment and validity are trivially identical.
        Some(unsafe { std::slice::from_raw_parts(s.as_ptr() as *const B, s.len()) })
    } else {
        None
    }
}

/// Owned counterpart of [`same_lane_slice`].
fn same_lane_vec<A: 'static, B: 'static>(v: Vec<A>) -> Option<Vec<B>> {
    if TypeId::of::<A>() == TypeId::of::<B>() {
        let mut v = std::mem::ManuallyDrop::new(v);
        // SAFETY: identical types (TypeId equality), so pointer, length and
        // capacity transfer verbatim; ManuallyDrop prevents a double free.
        Some(unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut B, v.len(), v.capacity()) })
    } else {
        None
    }
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Xla(_) => "xla",
        }
    }

    /// Compute updated values for every vertex in the shard's interval.
    ///
    /// `src` is the full SrcVertexArray, `out_deg` the full out-degree
    /// array; the returned vec has `csr.num_vertices()` entries (the
    /// interval `[csr.lo, csr.hi)`).
    pub fn process_shard<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        app: &P,
        csr: &Csr,
        src: &[V],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) -> Result<Vec<V>> {
        match self {
            Backend::Native => Ok(native_shard(app, csr, src, out_deg, ctx)),
            Backend::Xla(rt) => {
                // the AOT artifacts cover the f32 lane's three kernels; any
                // other lane (or KernelKind::None) runs the native loop
                if app.kernel() != KernelKind::None {
                    if let (Some(app32), Some(src32)) =
                        (app.as_f32_program(), same_lane_slice::<V, f32>(src))
                    {
                        let out = xla_shard(rt, app32, csr, src32, out_deg, ctx)?;
                        return Ok(same_lane_vec::<f32, V>(out)
                            .expect("f32 program on a non-f32 lane"));
                    }
                }
                Ok(native_shard(app, csr, src, out_deg, ctx))
            }
        }
    }
}

// ---- row-streaming edge sources --------------------------------------------
//
// The native update is a fold over (row, src, weight) streams.  Abstracting
// the stream behind [`EdgeSource`] lets one monomorphized loop consume a
// decoded CSR, a serialized shard buffer walked in place, or a
// delta-varint payload decoded on the fly — the compressed-domain gather.
// Every source visits rows and edges in exactly the order the decoded CSR
// stores them, so per-vertex fold order (and therefore every float result)
// is bit-identical across representations; each source may also cover just
// a sub-range of rows, which is what the engine's intra-shard chunks
// schedule across cores.

/// A stream of CSR rows: call [`Self::next_row`] exactly
/// [`Self::num_rows`] times, in order.
pub trait EdgeSource {
    /// Global vertex id of the first row this source covers.
    fn first_vertex(&self) -> VertexId;
    /// Rows covered (a whole shard interval or one chunk of it).
    fn num_rows(&self) -> usize;
    /// Stream the next row's in-edges, in storage order, into
    /// `f(src_id, weight)` (weight 1.0 on unweighted shards).
    fn next_row<F: FnMut(VertexId, Weight)>(&mut self, f: F) -> Result<()>;

    /// Hand the next row's edges to `k` as contiguous slices when the
    /// representation stores them that way (decoded CSR; aligned
    /// little-endian payload views), consuming the row and returning
    /// `Some(k(cols, wgts))`.  `wgts` is empty on unweighted rows.
    /// `Ok(None)` means "no contiguous run here" and leaves the row
    /// **unconsumed** so the caller can fall back to [`Self::next_row`];
    /// cursor-based sources (delta-varint, delta merges) keep this
    /// default and always take the scalar path.
    fn next_row_run<T, K: FnOnce(&[VertexId], &[Weight]) -> T>(
        &mut self,
        _k: K,
    ) -> Result<Option<T>> {
        Ok(None)
    }
}

/// Rows of a decoded [`Csr`] (optionally a sub-range).
pub struct CsrRows<'a> {
    csr: &'a Csr,
    row: usize,
    end: usize,
    start_vertex: VertexId,
}

impl<'a> CsrRows<'a> {
    pub fn new(csr: &'a Csr, rows: std::ops::Range<usize>) -> Self {
        debug_assert!(rows.end <= csr.num_vertices());
        Self {
            csr,
            row: rows.start,
            end: rows.end,
            start_vertex: csr.lo + rows.start as VertexId,
        }
    }
}

impl EdgeSource for CsrRows<'_> {
    fn first_vertex(&self) -> VertexId {
        self.start_vertex
    }

    fn num_rows(&self) -> usize {
        self.end - (self.start_vertex - self.csr.lo) as usize
    }

    #[inline]
    fn next_row<F: FnMut(VertexId, Weight)>(&mut self, mut f: F) -> Result<()> {
        anyhow::ensure!(self.row < self.end, "csr row source exhausted");
        let s = self.csr.row_ptr[self.row] as usize;
        let e = self.csr.row_ptr[self.row + 1] as usize;
        if self.csr.wgt.is_empty() {
            for k in s..e {
                f(self.csr.col[k], 1.0);
            }
        } else {
            for k in s..e {
                f(self.csr.col[k], self.csr.wgt[k]);
            }
        }
        self.row += 1;
        Ok(())
    }

    #[inline]
    fn next_row_run<T, K: FnOnce(&[VertexId], &[Weight]) -> T>(
        &mut self,
        k: K,
    ) -> Result<Option<T>> {
        anyhow::ensure!(self.row < self.end, "csr row source exhausted");
        let s = self.csr.row_ptr[self.row] as usize;
        let e = self.csr.row_ptr[self.row + 1] as usize;
        let wgts = if self.csr.wgt.is_empty() { &[][..] } else { &self.csr.wgt[s..e] };
        self.row += 1;
        Ok(Some(k(&self.csr.col[s..e], wgts)))
    }
}

/// Rows of a serialized shard buffer, read in place through a validated
/// [`PayloadView`] — no `row_ptr`/`col`/`wgt` vectors are ever built.
pub struct ViewRows<'a> {
    view: PayloadView<'a>,
    row: usize,
    end: usize,
    start_vertex: VertexId,
}

impl<'a> ViewRows<'a> {
    pub fn new(view: PayloadView<'a>, rows: std::ops::Range<usize>) -> Self {
        debug_assert!(rows.end <= view.num_rows());
        let start_vertex = view.lo() + rows.start as VertexId;
        Self { view, row: rows.start, end: rows.end, start_vertex }
    }
}

impl EdgeSource for ViewRows<'_> {
    fn first_vertex(&self) -> VertexId {
        self.start_vertex
    }

    fn num_rows(&self) -> usize {
        self.end - (self.start_vertex - self.view.lo()) as usize
    }

    #[inline]
    fn next_row<F: FnMut(VertexId, Weight)>(&mut self, mut f: F) -> Result<()> {
        anyhow::ensure!(self.row < self.end, "view row source exhausted");
        let s = self.view.row_ptr(self.row);
        let e = self.view.row_ptr(self.row + 1);
        if self.view.is_weighted() {
            for k in s..e {
                f(self.view.col(k), self.view.weight(k));
            }
        } else {
            for k in s..e {
                f(self.view.col(k), 1.0);
            }
        }
        self.row += 1;
        Ok(())
    }

    #[inline]
    fn next_row_run<T, K: FnOnce(&[VertexId], &[Weight]) -> T>(
        &mut self,
        k: K,
    ) -> Result<Option<T>> {
        anyhow::ensure!(self.row < self.end, "view row source exhausted");
        let s = self.view.row_ptr(self.row);
        let e = self.view.row_ptr(self.row + 1);
        // an unaligned (or big-endian) buffer yields no runs — scalar path
        let Some(cols) = self.view.col_run(s, e) else { return Ok(None) };
        let wgts = if self.view.is_weighted() {
            match self.view.weight_run(s, e) {
                Some(w) => w,
                None => return Ok(None),
            }
        } else {
            &[][..]
        };
        self.row += 1;
        Ok(Some(k(cols, wgts)))
    }
}

/// Rows decoded straight from a delta-varint payload chunk — the fully
/// compressed-domain source (nothing is materialized at any point).
pub struct DvRows<'a> {
    cursor: DvCursor<'a>,
    start_vertex: VertexId,
    rows: usize,
}

impl<'a> DvRows<'a> {
    /// `lo` is the payload's interval start (`DvPlan::lo`); the cursor
    /// must come from the same plan + payload.
    pub fn new(cursor: DvCursor<'a>, lo: VertexId, start_row: usize, rows: usize) -> Self {
        Self { cursor, start_vertex: lo + start_row as VertexId, rows }
    }
}

impl EdgeSource for DvRows<'_> {
    fn first_vertex(&self) -> VertexId {
        self.start_vertex
    }

    fn num_rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn next_row<F: FnMut(VertexId, Weight)>(&mut self, f: F) -> Result<()> {
        self.cursor.next_row(f)
    }
}

/// Merge a base-shard row stream with the shard's resident delta state
/// ([`crate::storage::delta::DeltaShard`]) inside the fold: each row
/// yields the base edges (minus tombstoned sources) in base order, then
/// the inserted edges in insertion order — exactly the row layout a
/// from-scratch preprocess of the final edge list produces, which is what
/// makes delta-merged execution bit-identical to a full rebuild on every
/// value lane.  Wraps any inner source, so the decoded, in-place-view and
/// delta-varint paths all mutate through the same few lines.
pub struct DeltaRows<'a, S: EdgeSource> {
    inner: S,
    delta: &'a crate::storage::delta::DeltaShard,
    /// Shard-local index of the next row to stream.
    row: usize,
    end: usize,
    start_vertex: VertexId,
    rows: usize,
}

impl<'a, S: EdgeSource> DeltaRows<'a, S> {
    /// `start_row` is the shard-local row the inner source begins at (the
    /// chunk offset); `inner` must cover exactly `rows` rows from there.
    pub fn new(
        inner: S,
        delta: &'a crate::storage::delta::DeltaShard,
        start_row: usize,
        rows: usize,
    ) -> Self {
        debug_assert_eq!(inner.num_rows(), rows);
        debug_assert_eq!(inner.first_vertex(), delta.lo + start_row as VertexId);
        Self {
            inner,
            delta,
            row: start_row,
            end: start_row + rows,
            start_vertex: delta.lo + start_row as VertexId,
            rows,
        }
    }
}

impl<S: EdgeSource> EdgeSource for DeltaRows<'_, S> {
    fn first_vertex(&self) -> VertexId {
        self.start_vertex
    }

    fn num_rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn next_row<F: FnMut(VertexId, Weight)>(&mut self, mut f: F) -> Result<()> {
        anyhow::ensure!(self.row < self.end, "delta row source exhausted");
        let r = self.row;
        self.row += 1;
        let tombs = self.delta.row_tombs(r);
        if tombs.is_empty() {
            self.inner.next_row(&mut f)?;
        } else {
            self.inner.next_row(|u, w| {
                if tombs.binary_search(&u).is_err() {
                    f(u, w);
                }
            })?;
        }
        let (s, e) = (
            self.delta.ins_row_ptr[r] as usize,
            self.delta.ins_row_ptr[r + 1] as usize,
        );
        for k in s..e {
            f(self.delta.ins_col[k], self.delta.ins_weight(k));
        }
        Ok(())
    }
}

/// Stream-fold any [`EdgeSource`] through the program, writing one value
/// per row into `out` (`out.len() == source.num_rows()`).  This is the one
/// native inner loop: the decoded path runs it over [`CsrRows`], so the
/// compressed-domain paths are bit-identical to it by construction.  The
/// common (gather, reduce) shapes are monomorphized (§Perf: ~2.3× on
/// PageRank) — `apply` runs once per vertex and stays virtual.
pub fn process_rows<V: VertexValue, P: VertexProgram<V> + ?Sized, S: EdgeSource>(
    app: &P,
    source: &mut S,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
    out: &mut [V],
) -> Result<()> {
    process_rows_cfg(app, source, src, out_deg, ctx, simd::enabled_default(), out)
}

/// [`process_rows`] with an explicit SIMD toggle.  When `simd` is on and
/// the source can hand whole rows as contiguous runs
/// ([`EdgeSource::next_row_run`]), each specialized arm folds the run
/// through the vectorized kernels in [`simd`]; rows (or sources) without
/// runs fall back to the per-edge scalar fold inside the same call, so
/// results are bit-identical either way (see `simd`'s module docs for why
/// that holds per reduction).
pub fn process_rows_cfg<V: VertexValue, P: VertexProgram<V> + ?Sized, S: EdgeSource>(
    app: &P,
    source: &mut S,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
    simd: bool,
    out: &mut [V],
) -> Result<()> {
    match (app.gather_kind(), app.reduce()) {
        (GatherKind::RankOverOutDeg, Reduce::Sum) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, _w: Weight| {
                    let d = out_deg[u];
                    // branchless dangling-source guard: 0 contribution
                    acc.vadd(if d == 0 { V::vzero() } else { src[u].div_deg(d) })
                };
            if simd {
                let run = |cols: &[VertexId], _wgts: &[Weight]| {
                    simd::sum_map(cols, |u| {
                        let d = out_deg[u as usize];
                        if d == 0 { V::vzero() } else { src[u as usize].div_deg(d) }
                    })
                };
                stream_fold_runs(app, source, src, ctx, V::vzero(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vzero(), fold, out)
            }
        }
        (GatherKind::PlusOne, Reduce::Min) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, _w: Weight| acc.vmin(src[u].vadd(V::vone()));
            if simd {
                let run = |cols: &[VertexId], _wgts: &[Weight]| {
                    simd::min_map(cols, |u| src[u as usize].vadd(V::vone()))
                };
                stream_fold_runs(app, source, src, ctx, V::vmax_value(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vmax_value(), fold, out)
            }
        }
        (GatherKind::PlusWeight, Reduce::Min) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, w: Weight| acc.vmin(src[u].vadd(V::from_weight(w)));
            if simd {
                let run = |cols: &[VertexId], wgts: &[Weight]| {
                    if wgts.is_empty() {
                        // unweighted rows stream w = 1.0
                        simd::min_map(cols, |u| src[u as usize].vadd(V::from_weight(1.0)))
                    } else {
                        simd::min_zip(cols, wgts, |u, w| {
                            src[u as usize].vadd(V::from_weight(w))
                        })
                    }
                };
                stream_fold_runs(app, source, src, ctx, V::vmax_value(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vmax_value(), fold, out)
            }
        }
        (GatherKind::PlusWeight, Reduce::Sum) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, w: Weight| acc.vadd(src[u].vadd(V::from_weight(w)));
            if simd {
                let run = |cols: &[VertexId], wgts: &[Weight]| {
                    if wgts.is_empty() {
                        // unweighted rows stream w = 1.0
                        simd::sum_map(cols, |u| src[u as usize].vadd(V::from_weight(1.0)))
                    } else {
                        simd::sum_zip(cols, wgts, |u, w| {
                            src[u as usize].vadd(V::from_weight(w))
                        })
                    }
                };
                stream_fold_runs(app, source, src, ctx, V::vzero(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vzero(), fold, out)
            }
        }
        (GatherKind::PlusWeight, Reduce::Max) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, w: Weight| acc.vmax(src[u].vadd(V::from_weight(w)));
            if simd {
                let run = |cols: &[VertexId], wgts: &[Weight]| {
                    if wgts.is_empty() {
                        simd::max_map(cols, |u| src[u as usize].vadd(V::from_weight(1.0)))
                    } else {
                        simd::max_zip(cols, wgts, |u, w| {
                            src[u as usize].vadd(V::from_weight(w))
                        })
                    }
                };
                stream_fold_runs(app, source, src, ctx, V::vmin_value(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vmin_value(), fold, out)
            }
        }
        (GatherKind::Identity, Reduce::Min) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, _w: Weight| acc.vmin(src[u]);
            if simd {
                let run = |cols: &[VertexId], _wgts: &[Weight]| {
                    simd::min_map(cols, |u| src[u as usize])
                };
                stream_fold_runs(app, source, src, ctx, V::vmax_value(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vmax_value(), fold, out)
            }
        }
        (GatherKind::Identity, Reduce::Sum) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, _w: Weight| acc.vadd(src[u]);
            if simd {
                let run = |cols: &[VertexId], _wgts: &[Weight]| {
                    simd::sum_map(cols, |u| src[u as usize])
                };
                stream_fold_runs(app, source, src, ctx, V::vzero(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vzero(), fold, out)
            }
        }
        (GatherKind::Identity, Reduce::Max) => {
            let fold =
                #[inline(always)]
                |acc: V, u: usize, _w: Weight| acc.vmax(src[u]);
            if simd {
                let run = |cols: &[VertexId], _wgts: &[Weight]| {
                    simd::max_map(cols, |u| src[u as usize])
                };
                stream_fold_runs(app, source, src, ctx, V::vmin_value(), fold, run, out)
            } else {
                stream_fold(app, source, src, ctx, V::vmin_value(), fold, out)
            }
        }
        _ => stream_fold_generic(app, source, src, out_deg, ctx, out),
    }
}

/// Monomorphized inner loop: `fold` is inlined per edge and receives the
/// source index plus the edge's weight.
#[inline]
fn stream_fold<
    V: VertexValue,
    P: VertexProgram<V> + ?Sized,
    S: EdgeSource,
    F: Fn(V, usize, Weight) -> V,
>(
    app: &P,
    source: &mut S,
    src: &[V],
    ctx: &ProgramContext,
    identity: V,
    fold: F,
    out: &mut [V],
) -> Result<()> {
    debug_assert_eq!(out.len(), source.num_rows());
    let lo = source.first_vertex() as usize;
    for (i, slot) in out.iter_mut().enumerate() {
        let mut acc = identity;
        source.next_row(|u, w| acc = fold(acc, u as usize, w))?;
        *slot = app.apply(acc, src[lo + i], ctx);
    }
    Ok(())
}

/// [`stream_fold`] with a per-row run kernel: rows the source hands out as
/// contiguous slices go through `run` (the vectorized fold), rows it
/// cannot fall back to the scalar `fold` — both computing the same
/// reduction from the same `identity`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn stream_fold_runs<
    V: VertexValue,
    P: VertexProgram<V> + ?Sized,
    S: EdgeSource,
    F: Fn(V, usize, Weight) -> V,
    R: Fn(&[VertexId], &[Weight]) -> V,
>(
    app: &P,
    source: &mut S,
    src: &[V],
    ctx: &ProgramContext,
    identity: V,
    fold: F,
    run: R,
    out: &mut [V],
) -> Result<()> {
    debug_assert_eq!(out.len(), source.num_rows());
    let lo = source.first_vertex() as usize;
    for (i, slot) in out.iter_mut().enumerate() {
        let acc = match source.next_row_run(&run)? {
            Some(v) => v,
            None => {
                let mut a = identity;
                source.next_row(|u, w| a = fold(a, u as usize, w))?;
                a
            }
        };
        *slot = app.apply(acc, src[lo + i], ctx);
    }
    Ok(())
}

/// Fallback for `GatherKind::Custom` programs: virtual `gather` per edge.
fn stream_fold_generic<V: VertexValue, P: VertexProgram<V> + ?Sized, S: EdgeSource>(
    app: &P,
    source: &mut S,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
    out: &mut [V],
) -> Result<()> {
    debug_assert_eq!(out.len(), source.num_rows());
    let reduce = app.reduce();
    let lo = source.first_vertex() as usize;
    for (i, slot) in out.iter_mut().enumerate() {
        let mut acc = reduce.identity();
        source.next_row(|u, w| {
            let u = u as usize;
            acc = reduce.combine(acc, app.gather(src[u], out_deg[u], w));
        })?;
        *slot = app.apply(acc, src[lo + i], ctx);
    }
    Ok(())
}

/// Pure-rust whole-shard update: [`process_rows`] over the decoded CSR.
fn native_shard<V: VertexValue, P: VertexProgram<V> + ?Sized>(
    app: &P,
    csr: &Csr,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Vec<V> {
    let n = csr.num_vertices();
    let mut out = vec![V::vzero(); n];
    process_rows(app, &mut CsrRows::new(csr, 0..n), src, out_deg, ctx, &mut out)
        .expect("decoded CSR rows cannot fail to stream");
    out
}

/// Fallback for `GatherKind::Custom` programs (and the oracle the
/// specialization tests compare against).
fn generic_shard<V: VertexValue, P: VertexProgram<V> + ?Sized>(
    app: &P,
    csr: &Csr,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Vec<V> {
    let reduce = app.reduce();
    let n = csr.num_vertices();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let s = csr.row_ptr[i] as usize;
        let e = csr.row_ptr[i + 1] as usize;
        let mut acc = reduce.identity();
        for k in s..e {
            let u = csr.col[k] as usize;
            acc = reduce.combine(acc, app.gather(src[u], out_deg[u], csr.weight(k)));
        }
        let old = src[csr.lo as usize + i];
        out.push(app.apply(acc, old, ctx));
    }
    out
}

/// Three-layer shard update: gather contributions on the rust side (weights
/// included), run the AOT artifact for reduce+apply.  Shards wider than the
/// kernel's edge capacity are chunked; partial reductions chain through the
/// monoid (sum: add partials via raw `segsum`; min: thread `old` through
/// `relaxmin` calls).
fn xla_shard(
    rt: &ShardRuntime,
    app: &dyn VertexProgram<f32>,
    csr: &Csr,
    src: &[f32],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Result<Vec<f32>> {
    let n = csr.num_vertices();
    let e_cap = rt.geometry.e_max;
    anyhow::ensure!(
        n <= rt.geometry.v_max,
        "shard interval {} wider than kernel V_MAX {}",
        n,
        rt.geometry.v_max
    );

    // gather: one contribution + local dst index per edge
    let m = csr.num_edges();
    let mut contrib = Vec::with_capacity(m);
    let mut dst_local = Vec::with_capacity(m);
    for i in 0..n {
        let s = csr.row_ptr[i] as usize;
        let e = csr.row_ptr[i + 1] as usize;
        for k in s..e {
            let u = csr.col[k] as usize;
            contrib.push(app.gather(src[u], out_deg[u], csr.weight(k)));
            dst_local.push(i as u32);
        }
    }
    let old = &src[csr.lo as usize..csr.hi as usize];

    match app.kernel() {
        KernelKind::PrAffine => {
            let inv_n = 1.0 / ctx.num_vertices.max(1) as f32;
            if m <= e_cap {
                rt.pr_shard(&contrib, &dst_local, inv_n, n)
            } else {
                // chunked: raw sums per chunk, affine apply on the rust side
                let mut sums = vec![0.0f32; n];
                for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                    let part = rt.segsum_shard(c, d, n)?;
                    for (a, b) in sums.iter_mut().zip(part) {
                        *a += b;
                    }
                }
                Ok(sums
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| app.apply(s, old[i], ctx))
                    .collect())
            }
        }
        KernelKind::RelaxMin => {
            let mut cur = old.to_vec();
            if m == 0 {
                return Ok(cur);
            }
            for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                cur = rt.relaxmin_shard(c, d, &cur, n)?;
            }
            Ok(cur)
        }
        KernelKind::RawSum => {
            let mut sums = vec![0.0f32; n];
            if m == 0 {
                return Ok(sums);
            }
            for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                let part = rt.segsum_shard(c, d, n)?;
                for (a, b) in sums.iter_mut().zip(part) {
                    *a += b;
                }
            }
            Ok(sums)
        }
        KernelKind::None => unreachable!("KernelKind::None is filtered in process_shard"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{LabelProp, MaxDeg, PageRank, Sssp, Wcc, WeightedSssp};

    fn fixture() -> (Csr, Vec<f32>, Vec<u32>) {
        // interval [0,4); edges (1,0),(2,0),(3,1),(0,2),(1,2)
        let csr = Csr::from_edges(0, 4, &[(1, 0), (2, 0), (3, 1), (0, 2), (1, 2)]);
        let src = vec![0.25f32, 0.25, 0.25, 0.25];
        let out_deg = vec![1u32, 2, 1, 1];
        (csr, src, out_deg)
    }

    #[test]
    fn specialized_loops_match_generic_fallback() {
        // the gather_kind hint must never change results: compare each
        // f32 app's specialized path against generic_shard on a random
        // weighted shard
        use crate::apps::{Bfs, SpMv};
        use crate::graph::generator;
        let edges: Vec<(u32, u32)> = generator::rmat(9, 3000, generator::RmatParams::default(), 5)
            .into_iter()
            .filter(|&(_, d)| d < 128)
            .collect();
        let weights = generator::synth_weights(&edges, 11);
        let csr = Csr::from_edges_weighted(0, 128, &edges, &weights);
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(2);
        let src: Vec<f32> = (0..512).map(|_| rng.next_f32()).collect();
        let out_deg: Vec<u32> = (0..512).map(|_| rng.gen_range(20) as u32).collect();
        let ctx = ProgramContext { num_vertices: 512 };
        let apps: Vec<Box<dyn VertexProgram>> = vec![
            Box::new(PageRank::default()),
            Box::new(Sssp { source: 0 }),
            Box::new(Wcc),
            Box::new(Bfs { root: 0 }),
            Box::new(SpMv { seed: 1 }),
            Box::new(WeightedSssp { source: 0 }),
        ];
        for app in &apps {
            let fast = native_shard(app.as_ref(), &csr, &src, &out_deg, &ctx);
            let slow = generic_shard(app.as_ref(), &csr, &src, &out_deg, &ctx);
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-6,
                    "{} v{i}: {a} vs {b}",
                    app.name()
                );
            }
        }
    }

    #[test]
    fn typed_lanes_specialize_identically_too() {
        // u64 (Identity, Min) and u32 (Custom -> generic) lanes through the
        // same machinery
        let csr = Csr::from_edges(0, 4, &[(1, 0), (2, 0), (3, 1), (0, 2), (1, 2)]);
        let ctx = ProgramContext { num_vertices: 4 };
        let out_deg = vec![1u32, 2, 1, 1];

        let lp = LabelProp;
        let src: Vec<u64> = (0..4).collect();
        let fast = native_shard(&lp, &csr, &src, &out_deg, &ctx);
        let slow = generic_shard(&lp, &csr, &src, &out_deg, &ctx);
        assert_eq!(fast, slow);
        // v0: min(0, {1,2}) = 0; v1: min(1, {3}) = 1; v2: min(2, {0,1}) = 0
        assert_eq!(fast, vec![0, 1, 0, 3]);

        let md = MaxDeg;
        let src: Vec<u32> = vec![0, 0, 0, 0];
        let got = native_shard(&md, &csr, &src, &out_deg, &ctx);
        // v0 sees sources {1,2} (out_deg 2,1) => 2; v2 sees {0,1} => 2
        assert_eq!(got, vec![2, 1, 2, 0]);
    }

    #[test]
    fn native_pagerank_matches_reference_update() {
        let (csr, src, out_deg) = fixture();
        let app = PageRank::default();
        let ctx = ProgramContext { num_vertices: 4 };
        let got = Backend::Native.process_shard(&app, &csr, &src, &out_deg, &ctx).unwrap();
        for (i, &g) in got.iter().enumerate() {
            let want = app.update(i as u32, csr.in_neighbors(i as u32), &src, &out_deg, &ctx);
            assert!((g - want).abs() < 1e-7, "v{i}: {g} vs {want}");
        }
    }

    #[test]
    fn native_min_apps_match_reference() {
        let (csr, _, out_deg) = fixture();
        let ctx = ProgramContext { num_vertices: 4 };
        let sssp = Sssp { source: 1 };
        let src = vec![f32::INFINITY, 0.0, f32::INFINITY, f32::INFINITY];
        let got = Backend::Native.process_shard(&sssp, &csr, &src, &out_deg, &ctx).unwrap();
        for (i, &g) in got.iter().enumerate() {
            let want = sssp.update(i as u32, csr.in_neighbors(i as u32), &src, &out_deg, &ctx);
            assert_eq!(g, want, "v{i}");
        }
        let wcc = Wcc;
        let src: Vec<f32> = (0..4).map(|v| v as f32).collect();
        let got = Backend::Native.process_shard(&wcc, &csr, &src, &out_deg, &ctx).unwrap();
        // v0: min(old=0, src{1,2}) = 0; v1: min(1, src{3}) = 1;
        // v2: min(2, src{0,1}) = 0; v3: no in-edges => old = 3
        assert_eq!(got, vec![0.0, 1.0, 0.0, 3.0]);
    }

    #[test]
    fn weighted_shard_relaxes_with_real_weights() {
        // 0 -(0.5)-> 1, 0 -(2.5)-> 2, 1 -(0.25)-> 2 inside [0,3)
        let csr = Csr::from_edges_weighted(
            0,
            3,
            &[(0, 1), (0, 2), (1, 2)],
            &[0.5, 2.5, 0.25],
        );
        let app = WeightedSssp { source: 0 };
        let ctx = ProgramContext { num_vertices: 3 };
        let src = vec![0.0f32, 0.5, f32::INFINITY];
        let out_deg = vec![2u32, 1, 0];
        let got = Backend::Native.process_shard(&app, &csr, &src, &out_deg, &ctx).unwrap();
        // v2: min(0 + 2.5, 0.5 + 0.25) = 0.75
        assert_eq!(got, vec![0.0, 0.5, 0.75]);
    }

    /// Run `app` over every source representation (decoded rows, in-place
    /// payload view, delta-varint cursor) at several chunk splits and
    /// demand bit-identical output everywhere.
    fn assert_all_sources_agree<V: VertexValue>(
        app: &dyn VertexProgram<V>,
        csr: &Csr,
        src: &[V],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) {
        use crate::cache::deltavarint;
        use crate::storage::shardfile;
        let n = csr.num_vertices();
        let want = native_shard(app, csr, src, out_deg, ctx);

        let payload = shardfile::to_bytes(csr);
        let layout = shardfile::parse_layout(&payload).unwrap();
        let dv = deltavarint::encode(csr);
        // dv normalizes row order; its oracle is the decoded-dv CSR
        let dv_csr = deltavarint::decode(&dv).unwrap();
        let dv_want = native_shard(app, &dv_csr, src, out_deg, ctx);

        for chunk_rows in [n.max(1), 1, 3] {
            let mut got = vec![V::vzero(); n];
            for start in (0..n).step_by(chunk_rows) {
                let end = (start + chunk_rows).min(n);
                let mut rows = CsrRows::new(csr, start..end);
                process_rows(app, &mut rows, src, out_deg, ctx, &mut got[start..end]).unwrap();
            }
            assert_eq!(got, want, "CsrRows chunk_rows={chunk_rows}");

            let mut got = vec![V::vzero(); n];
            for start in (0..n).step_by(chunk_rows) {
                let end = (start + chunk_rows).min(n);
                let mut rows = ViewRows::new(layout.view(&payload), start..end);
                process_rows(app, &mut rows, src, out_deg, ctx, &mut got[start..end]).unwrap();
            }
            assert_eq!(got, want, "ViewRows chunk_rows={chunk_rows}");

            let plan = deltavarint::plan(&dv, chunk_rows).unwrap();
            let mut got = vec![V::vzero(); n];
            for chunk in &plan.chunks {
                let mut rows = DvRows::new(
                    plan.cursor(&dv, chunk),
                    plan.lo,
                    chunk.start_row,
                    chunk.end_row - chunk.start_row,
                );
                process_rows(
                    app,
                    &mut rows,
                    src,
                    out_deg,
                    ctx,
                    &mut got[chunk.start_row..chunk.end_row],
                )
                .unwrap();
            }
            assert_eq!(got, dv_want, "DvRows chunk_rows={chunk_rows}");
        }
    }

    #[test]
    fn compressed_domain_sources_match_decoded_bit_for_bit() {
        use crate::apps::Bfs;
        use crate::graph::generator;
        let edges: Vec<(u32, u32)> =
            generator::rmat(8, 1500, generator::RmatParams::default(), 21)
                .into_iter()
                .filter(|&(_, d)| d < 64)
                .collect();
        let weights = generator::synth_weights(&edges, 5);
        let ctx = ProgramContext { num_vertices: 256 };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(9);
        let out_deg: Vec<u32> = (0..256).map(|_| rng.gen_range(16) as u32).collect();

        for weighted in [false, true] {
            let csr = if weighted {
                Csr::from_edges_weighted(0, 64, &edges, &weights)
            } else {
                Csr::from_edges(0, 64, &edges)
            };
            // f32 lane: every gather/reduce shape incl. the generic path
            let src: Vec<f32> = (0..256).map(|v| (v as f32) * 0.25 + 0.5).collect();
            let f32_apps: Vec<Box<dyn VertexProgram>> = vec![
                Box::new(PageRank::default()),
                Box::new(Sssp { source: 0 }),
                Box::new(WeightedSssp { source: 0 }),
                Box::new(Wcc),
                Box::new(Bfs { root: 0 }),
            ];
            for app in &f32_apps {
                assert_all_sources_agree(app.as_ref(), &csr, &src, &out_deg, &ctx);
            }
            // integer + wide lanes
            let src64: Vec<u64> = (0..256).collect();
            assert_all_sources_agree::<u64>(&LabelProp, &csr, &src64, &out_deg, &ctx);
            let src32: Vec<u32> = vec![0; 256];
            assert_all_sources_agree::<u32>(&MaxDeg, &csr, &src32, &out_deg, &ctx);
            let srcf64: Vec<f64> = (0..256).map(|v| (v as f64) * 0.125).collect();
            assert_all_sources_agree::<f64>(
                &crate::apps::SpMv64::default(),
                &csr,
                &srcf64,
                &out_deg,
                &ctx,
            );
        }
    }

    #[test]
    fn delta_rows_equal_merged_csr_on_every_source_and_chunking() {
        use crate::cache::deltavarint;
        use crate::graph::generator;
        use crate::storage::delta::DeltaShard;
        use crate::storage::shardfile;
        // base shard [0, 32) plus a delta with tombstones and inserts
        let edges: Vec<(u32, u32)> = generator::erdos_renyi(64, 400, 17)
            .into_iter()
            .filter(|&(_, d)| d < 32)
            .collect();
        let weights = generator::synth_weights(&edges, 3);
        for weighted in [false, true] {
            let base = if weighted {
                Csr::from_edges_weighted(0, 32, &edges, &weights)
            } else {
                Csr::from_edges(0, 32, &edges)
            };
            // tombstone a few real base edges, insert a few new ones
            let mut tomb_rows: Vec<Vec<u32>> = vec![Vec::new(); 32];
            for r in (0..32).step_by(5) {
                if let Some(&u) = base.in_neighbors(r as u32).first() {
                    tomb_rows[r].push(u);
                }
            }
            let mut ins_rows: Vec<Vec<(u32, f32)>> = vec![Vec::new(); 32];
            for r in (0..32).step_by(3) {
                ins_rows[r].push(((r as u32 + 40) % 64, 0.5));
                ins_rows[r].push(((r as u32 + 41) % 64, 2.0));
            }
            let dropped = tomb_rows
                .iter()
                .enumerate()
                .map(|(r, t)| {
                    t.iter()
                        .map(|&u| {
                            base.in_neighbors(r as u32).iter().filter(|&&x| x == u).count()
                        })
                        .sum::<usize>()
                })
                .sum::<usize>() as u64;
            let delta = DeltaShard::from_rows(0, 32, &ins_rows, &tomb_rows, dropped, true);
            let merged = delta.merge(&base);
            let ctx = ProgramContext { num_vertices: 64 };
            let src: Vec<f32> = (0..64).map(|v| (v as f32) * 0.375 + 0.25).collect();
            let out_deg: Vec<u32> = (0..64).map(|v| (v * 7 % 5 + 1) as u32).collect();
            let app = PageRank::default();
            let want = native_shard(&app, &merged, &src, &out_deg, &ctx);

            let payload = shardfile::to_bytes(&base);
            let layout = shardfile::parse_layout(&payload).unwrap();
            let n = 32usize;
            for chunk_rows in [n, 1, 7] {
                // decoded base rows + delta
                let mut got = vec![0.0f32; n];
                for start in (0..n).step_by(chunk_rows) {
                    let end = (start + chunk_rows).min(n);
                    let mut rows = DeltaRows::new(
                        CsrRows::new(&base, start..end),
                        &delta,
                        start,
                        end - start,
                    );
                    process_rows(&app, &mut rows, &src, &out_deg, &ctx, &mut got[start..end])
                        .unwrap();
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got), bits(&want), "CsrRows+delta chunk={chunk_rows}");

                // in-place view + delta
                let mut got = vec![0.0f32; n];
                for start in (0..n).step_by(chunk_rows) {
                    let end = (start + chunk_rows).min(n);
                    let mut rows = DeltaRows::new(
                        ViewRows::new(layout.view(&payload), start..end),
                        &delta,
                        start,
                        end - start,
                    );
                    process_rows(&app, &mut rows, &src, &out_deg, &ctx, &mut got[start..end])
                        .unwrap();
                }
                assert_eq!(bits(&got), bits(&want), "ViewRows+delta chunk={chunk_rows}");
            }

            // delta-varint normalizes base row order; its oracle is the
            // merged dv-decoded base (same normalization)
            let dv = deltavarint::encode(&base);
            let dv_base = deltavarint::decode(&dv).unwrap();
            let dv_want = native_shard(&app, &delta.merge(&dv_base), &src, &out_deg, &ctx);
            let plan = deltavarint::plan(&dv, 7).unwrap();
            let mut got = vec![0.0f32; n];
            for chunk in &plan.chunks {
                let mut rows = DeltaRows::new(
                    DvRows::new(
                        plan.cursor(&dv, chunk),
                        plan.lo,
                        chunk.start_row,
                        chunk.end_row - chunk.start_row,
                    ),
                    &delta,
                    chunk.start_row,
                    chunk.end_row - chunk.start_row,
                );
                process_rows(
                    &app,
                    &mut rows,
                    &src,
                    &out_deg,
                    &ctx,
                    &mut got[chunk.start_row..chunk.end_row],
                )
                .unwrap();
            }
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&dv_want), "DvRows+delta");
        }
    }

    /// SIMD dispatch must be invisible: `process_rows_cfg(simd=true)` and
    /// `(simd=false)` produce the same bits on every source shape,
    /// including the unaligned-view fallback and odd chunkings.
    fn assert_simd_matches_scalar<V: VertexValue>(
        app: &dyn VertexProgram<V>,
        csr: &Csr,
        src: &[V],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) {
        use crate::storage::shardfile;
        let n = csr.num_vertices();
        let bits = |v: &[V]| {
            let mut b = Vec::new();
            v.iter().for_each(|x| x.write_le(&mut b));
            b
        };
        let mut scalar = vec![V::vzero(); n];
        process_rows_cfg(app, &mut CsrRows::new(csr, 0..n), src, out_deg, ctx, false, &mut scalar)
            .unwrap();
        for chunk_rows in [n.max(1), 1, 5] {
            let mut got = vec![V::vzero(); n];
            for start in (0..n).step_by(chunk_rows) {
                let end = (start + chunk_rows).min(n);
                let mut rows = CsrRows::new(csr, start..end);
                process_rows_cfg(app, &mut rows, src, out_deg, ctx, true, &mut got[start..end])
                    .unwrap();
            }
            assert_eq!(bits(&got), bits(&scalar), "{} CsrRows simd chunk={chunk_rows}", app.name());
        }
        let payload = shardfile::to_bytes(csr);
        let layout = shardfile::parse_layout(&payload).unwrap();
        let mut got = vec![V::vzero(); n];
        let mut rows = ViewRows::new(layout.view(&payload), 0..n);
        process_rows_cfg(app, &mut rows, src, out_deg, ctx, true, &mut got).unwrap();
        assert_eq!(bits(&got), bits(&scalar), "{} ViewRows simd", app.name());
        // misalign the payload by one byte: col_run must refuse the cast
        // and the scalar fallback inside the simd path must still match
        let mut shifted = vec![0u8; payload.len() + 1];
        shifted[1..].copy_from_slice(&payload);
        let layout2 = shardfile::parse_layout(&shifted[1..]).unwrap();
        let mut got = vec![V::vzero(); n];
        let mut rows = ViewRows::new(layout2.view(&shifted[1..]), 0..n);
        process_rows_cfg(app, &mut rows, src, out_deg, ctx, true, &mut got).unwrap();
        assert_eq!(bits(&got), bits(&scalar), "{} shifted ViewRows simd", app.name());
    }

    #[test]
    fn simd_folds_are_bit_identical_to_scalar() {
        use crate::apps::Bfs;
        use crate::graph::generator;
        let edges: Vec<(u32, u32)> =
            generator::rmat(8, 1500, generator::RmatParams::default(), 33)
                .into_iter()
                .filter(|&(_, d)| d < 64)
                .collect();
        let weights = generator::synth_weights(&edges, 13);
        let ctx = ProgramContext { num_vertices: 256 };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(77);
        let out_deg: Vec<u32> = (0..256).map(|_| rng.gen_range(16) as u32).collect();
        for weighted in [false, true] {
            let csr = if weighted {
                Csr::from_edges_weighted(0, 64, &edges, &weights)
            } else {
                Csr::from_edges(0, 64, &edges)
            };
            let src: Vec<f32> = (0..256).map(|v| (v as f32) * 0.25 + 0.5).collect();
            let f32_apps: Vec<Box<dyn VertexProgram>> = vec![
                Box::new(PageRank::default()),
                Box::new(Sssp { source: 0 }),
                Box::new(WeightedSssp { source: 0 }),
                Box::new(Wcc),
                Box::new(Bfs { root: 0 }),
            ];
            for app in &f32_apps {
                assert_simd_matches_scalar(app.as_ref(), &csr, &src, &out_deg, &ctx);
            }
            let src64: Vec<u64> = (0..256).collect();
            assert_simd_matches_scalar::<u64>(&LabelProp, &csr, &src64, &out_deg, &ctx);
            let src32: Vec<u32> = vec![0; 256];
            assert_simd_matches_scalar::<u32>(&MaxDeg, &csr, &src32, &out_deg, &ctx);
            let srcf64: Vec<f64> = (0..256).map(|v| (v as f64) * 0.125).collect();
            assert_simd_matches_scalar::<f64>(
                &crate::apps::SpMv64::default(),
                &csr,
                &srcf64,
                &out_deg,
                &ctx,
            );
        }
    }

    /// `(PlusWeight, Sum)` / `(PlusWeight, Max)` probe: no registry app
    /// declares these shapes yet, so a test-local program exercises the
    /// widened weighted arms against the generic virtual fallback.
    struct WeightedProbe {
        reduce: Reduce,
    }

    impl VertexProgram<f32> for WeightedProbe {
        fn name(&self) -> &'static str {
            "wprobe"
        }
        fn init(&self, v: VertexId, _ctx: &ProgramContext) -> f32 {
            (v as f32) * 0.5 + 0.25
        }
        fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
            true
        }
        fn gather(&self, src_val: f32, _src_out_deg: u32, weight: Weight) -> f32 {
            src_val.vadd(f32::from_weight(weight))
        }
        fn reduce(&self) -> Reduce {
            self.reduce
        }
        fn apply(&self, reduced: f32, old: f32, _ctx: &ProgramContext) -> f32 {
            match self.reduce {
                Reduce::Max => reduced.vmax(old),
                _ => reduced,
            }
        }
        fn kernel(&self) -> KernelKind {
            KernelKind::None
        }
        fn gather_kind(&self) -> GatherKind {
            GatherKind::PlusWeight
        }
    }

    /// Same probe on the u64 lane: weighted sums there reassociate across
    /// SIMD accumulators (`SUM_REASSOCIATES`), which must still be exact.
    struct WeightedSumU64;

    impl VertexProgram<u64> for WeightedSumU64 {
        fn name(&self) -> &'static str {
            "wsum64"
        }
        fn init(&self, v: VertexId, _ctx: &ProgramContext) -> u64 {
            v as u64
        }
        fn initially_active(&self, _v: VertexId, _ctx: &ProgramContext) -> bool {
            true
        }
        fn gather(&self, src_val: u64, _src_out_deg: u32, weight: Weight) -> u64 {
            src_val.vadd(u64::from_weight(weight))
        }
        fn reduce(&self) -> Reduce {
            Reduce::Sum
        }
        fn apply(&self, reduced: u64, _old: u64, _ctx: &ProgramContext) -> u64 {
            reduced
        }
        fn kernel(&self) -> KernelKind {
            KernelKind::None
        }
        fn gather_kind(&self) -> GatherKind {
            GatherKind::PlusWeight
        }
    }

    #[test]
    fn weighted_sum_and_max_arms_match_generic_and_scalar() {
        use crate::graph::generator;
        let edges: Vec<(u32, u32)> =
            generator::rmat(8, 1500, generator::RmatParams::default(), 33)
                .into_iter()
                .filter(|&(_, d)| d < 64)
                .collect();
        let weights = generator::synth_weights(&edges, 13);
        let ctx = ProgramContext { num_vertices: 256 };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(5);
        let out_deg: Vec<u32> = (0..256).map(|_| rng.gen_range(16) as u32).collect();
        let src: Vec<f32> = (0..256).map(|v| (v as f32) * 0.25 + 0.5).collect();
        let src64: Vec<u64> = (0..256).map(|v| v * 3 + 1).collect();
        for weighted in [false, true] {
            let csr = if weighted {
                Csr::from_edges_weighted(0, 64, &edges, &weights)
            } else {
                Csr::from_edges(0, 64, &edges)
            };
            for reduce in [Reduce::Sum, Reduce::Max] {
                let app = WeightedProbe { reduce };
                // the specialized arm must reproduce the virtual fallback
                // bit-for-bit (same serial order, same per-edge ops)
                let fast = native_shard(&app, &csr, &src, &out_deg, &ctx);
                let slow = generic_shard(&app, &csr, &src, &out_deg, &ctx);
                let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(b(&fast), b(&slow), "{reduce:?} weighted={weighted}");
                // and its SIMD path must match its own scalar path on
                // every source / chunking / alignment
                assert_simd_matches_scalar(&app, &csr, &src, &out_deg, &ctx);
            }
            assert_simd_matches_scalar::<u64>(&WeightedSumU64, &csr, &src64, &out_deg, &ctx);
        }
    }

    #[test]
    fn lane_casts_are_identity_only() {
        let xs = [1.0f32, 2.0];
        assert!(same_lane_slice::<f32, f32>(&xs).is_some());
        assert!(same_lane_slice::<f32, u32>(&xs).is_none());
        assert_eq!(same_lane_vec::<f32, f32>(vec![3.0]).unwrap(), vec![3.0]);
        assert!(same_lane_vec::<f32, f64>(vec![3.0f32]).is_none());
    }
}
