//! Compute backends for the per-shard update.
//!
//! * [`Backend::Native`] — pure-rust segmented reduce+apply; the fast path
//!   used by paper-scale benches.
//! * [`Backend::Xla`] — the three-layer path: gather in rust, reduce+apply
//!   in the AOT-compiled Pallas/JAX artifact via PJRT.  Proves the stack
//!   composes; used by examples, the e2e driver and equivalence tests.
//!
//! Both produce identical results (`tests/engine_equivalence.rs`).

use std::sync::Arc;

use anyhow::Result;

use crate::apps::{KernelKind, ProgramContext, VertexProgram};
use crate::graph::csr::Csr;
use crate::runtime::ShardRuntime;

/// Pluggable shard-update executor.
#[derive(Clone)]
pub enum Backend {
    Native,
    Xla(Arc<ShardRuntime>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Backend::Native"),
            Backend::Xla(_) => write!(f, "Backend::Xla"),
        }
    }
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Xla(_) => "xla",
        }
    }

    /// Compute updated values for every vertex in the shard's interval.
    ///
    /// `src` is the full SrcVertexArray, `out_deg` the full out-degree
    /// array; the returned vec has `csr.num_vertices()` entries (the
    /// interval `[csr.lo, csr.hi)`).
    pub fn process_shard(
        &self,
        app: &dyn VertexProgram,
        csr: &Csr,
        src: &[f32],
        out_deg: &[u32],
        ctx: &ProgramContext,
    ) -> Result<Vec<f32>> {
        match self {
            Backend::Native => Ok(native_shard(app, csr, src, out_deg, ctx)),
            Backend::Xla(rt) => xla_shard(rt, app, csr, src, out_deg, ctx),
        }
    }
}

/// Pure-rust shard update: walk CSR rows, gather + reduce + apply.
///
/// The generic path pays a virtual `gather` call per edge; the engine's
/// whole steady state is this loop, so the common (gather, reduce) shapes
/// are monomorphized below (§Perf: ~2.3× on PageRank).  `apply` runs once
/// per *vertex* and stays virtual.
fn native_shard(
    app: &dyn VertexProgram,
    csr: &Csr,
    src: &[f32],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Vec<f32> {
    use crate::apps::GatherKind;
    match (app.gather_kind(), app.reduce()) {
        (GatherKind::RankOverOutDeg, Reduce2::Sum) => specialized_shard(
            app,
            csr,
            src,
            ctx,
            0.0,
            #[inline(always)]
            |acc, u| {
                let d = out_deg[u];
                // branchless dangling-source guard: 0 contribution
                acc + if d == 0 { 0.0 } else { src[u] / d as f32 }
            },
        ),
        (GatherKind::PlusOne, Reduce2::Min) => specialized_shard(
            app,
            csr,
            src,
            ctx,
            f32::INFINITY,
            #[inline(always)]
            |acc: f32, u| acc.min(src[u] + 1.0),
        ),
        (GatherKind::Identity, Reduce2::Min) => specialized_shard(
            app,
            csr,
            src,
            ctx,
            f32::INFINITY,
            #[inline(always)]
            |acc: f32, u| acc.min(src[u]),
        ),
        (GatherKind::Identity, Reduce2::Sum) => specialized_shard(
            app,
            csr,
            src,
            ctx,
            0.0,
            #[inline(always)]
            |acc, u| acc + src[u],
        ),
        _ => generic_shard(app, csr, src, out_deg, ctx),
    }
}

// local alias so the match above reads cleanly
use crate::apps::Reduce as Reduce2;

/// Monomorphized inner loop: `fold` is inlined per edge.
#[inline]
fn specialized_shard<F: Fn(f32, usize) -> f32>(
    app: &dyn VertexProgram,
    csr: &Csr,
    src: &[f32],
    ctx: &ProgramContext,
    identity: f32,
    fold: F,
) -> Vec<f32> {
    let n = csr.num_vertices();
    let mut out = Vec::with_capacity(n);
    let row_ptr = &csr.row_ptr;
    let col = &csr.col;
    for i in 0..n {
        let s = row_ptr[i] as usize;
        let e = row_ptr[i + 1] as usize;
        let mut acc = identity;
        for &u in &col[s..e] {
            acc = fold(acc, u as usize);
        }
        let old = src[csr.lo as usize + i];
        out.push(app.apply(acc, old, ctx));
    }
    out
}

/// Fallback for `GatherKind::Custom` programs.
fn generic_shard(
    app: &dyn VertexProgram,
    csr: &Csr,
    src: &[f32],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Vec<f32> {
    let reduce = app.reduce();
    let n = csr.num_vertices();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let s = csr.row_ptr[i] as usize;
        let e = csr.row_ptr[i + 1] as usize;
        let mut acc = reduce.identity();
        for &u in &csr.col[s..e] {
            acc = reduce.combine(acc, app.gather(src[u as usize], out_deg[u as usize]));
        }
        let old = src[csr.lo as usize + i];
        out.push(app.apply(acc, old, ctx));
    }
    out
}

/// Three-layer shard update: gather contributions on the rust side, run the
/// AOT artifact for reduce+apply.  Shards wider than the kernel's edge
/// capacity are chunked; partial reductions chain through the monoid
/// (sum: add partials via raw `segsum`; min: thread `old` through
/// `relaxmin` calls).
fn xla_shard(
    rt: &ShardRuntime,
    app: &dyn VertexProgram,
    csr: &Csr,
    src: &[f32],
    out_deg: &[u32],
    ctx: &ProgramContext,
) -> Result<Vec<f32>> {
    let n = csr.num_vertices();
    let e_cap = rt.geometry.e_max;
    anyhow::ensure!(
        n <= rt.geometry.v_max,
        "shard interval {} wider than kernel V_MAX {}",
        n,
        rt.geometry.v_max
    );

    // gather: one contribution + local dst index per edge
    let m = csr.num_edges();
    let mut contrib = Vec::with_capacity(m);
    let mut dst_local = Vec::with_capacity(m);
    for i in 0..n {
        let s = csr.row_ptr[i] as usize;
        let e = csr.row_ptr[i + 1] as usize;
        for &u in &csr.col[s..e] {
            contrib.push(app.gather(src[u as usize], out_deg[u as usize]));
            dst_local.push(i as u32);
        }
    }
    let old = &src[csr.lo as usize..csr.hi as usize];

    match app.kernel() {
        KernelKind::PrAffine => {
            let inv_n = 1.0 / ctx.num_vertices.max(1) as f32;
            if m <= e_cap {
                rt.pr_shard(&contrib, &dst_local, inv_n, n)
            } else {
                // chunked: raw sums per chunk, affine apply on the rust side
                let mut sums = vec![0.0f32; n];
                for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                    let part = rt.segsum_shard(c, d, n)?;
                    for (a, b) in sums.iter_mut().zip(part) {
                        *a += b;
                    }
                }
                Ok(sums
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| app.apply(s, old[i], ctx))
                    .collect())
            }
        }
        KernelKind::RelaxMin => {
            let mut cur = old.to_vec();
            if m == 0 {
                return Ok(cur);
            }
            for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                cur = rt.relaxmin_shard(c, d, &cur, n)?;
            }
            Ok(cur)
        }
        KernelKind::RawSum => {
            let mut sums = vec![0.0f32; n];
            if m == 0 {
                return Ok(sums);
            }
            for (c, d) in contrib.chunks(e_cap).zip(dst_local.chunks(e_cap)) {
                let part = rt.segsum_shard(c, d, n)?;
                for (a, b) in sums.iter_mut().zip(part) {
                    *a += b;
                }
            }
            Ok(sums)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, Sssp, Wcc};

    fn fixture() -> (Csr, Vec<f32>, Vec<u32>) {
        // interval [0,4); edges (1,0),(2,0),(3,1),(0,2),(1,2)
        let csr = Csr::from_edges(0, 4, &[(1, 0), (2, 0), (3, 1), (0, 2), (1, 2)]);
        let src = vec![0.25f32, 0.25, 0.25, 0.25];
        let out_deg = vec![1u32, 2, 1, 1];
        (csr, src, out_deg)
    }

    #[test]
    fn specialized_loops_match_generic_fallback() {
        // the gather_kind hint must never change results: compare each
        // app's specialized path against generic_shard on a random shard
        use crate::apps::{Bfs, SpMv};
        use crate::graph::generator;
        let edges: Vec<(u32, u32)> = generator::rmat(9, 3000, generator::RmatParams::default(), 5)
            .into_iter()
            .filter(|&(_, d)| d < 128)
            .collect();
        let csr = Csr::from_edges(0, 128, &edges);
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(2);
        let src: Vec<f32> = (0..512).map(|_| rng.next_f32()).collect();
        let out_deg: Vec<u32> = (0..512).map(|_| rng.gen_range(20) as u32).collect();
        let ctx = ProgramContext { num_vertices: 512 };
        let apps: Vec<Box<dyn VertexProgram>> = vec![
            Box::new(PageRank::default()),
            Box::new(Sssp { source: 0 }),
            Box::new(Wcc),
            Box::new(Bfs { root: 0 }),
            Box::new(SpMv { seed: 1 }),
        ];
        for app in &apps {
            let fast = native_shard(app.as_ref(), &csr, &src, &out_deg, &ctx);
            let slow = generic_shard(app.as_ref(), &csr, &src, &out_deg, &ctx);
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-6,
                    "{} v{i}: {a} vs {b}",
                    app.name()
                );
            }
        }
    }

    #[test]
    fn native_pagerank_matches_reference_update() {
        let (csr, src, out_deg) = fixture();
        let app = PageRank::default();
        let ctx = ProgramContext { num_vertices: 4 };
        let got = Backend::Native.process_shard(&app, &csr, &src, &out_deg, &ctx).unwrap();
        for (i, &g) in got.iter().enumerate() {
            let want = app.update(i as u32, csr.in_neighbors(i as u32), &src, &out_deg, &ctx);
            assert!((g - want).abs() < 1e-7, "v{i}: {g} vs {want}");
        }
    }

    #[test]
    fn native_min_apps_match_reference() {
        let (csr, _, out_deg) = fixture();
        let ctx = ProgramContext { num_vertices: 4 };
        let sssp = Sssp { source: 1 };
        let src = vec![f32::INFINITY, 0.0, f32::INFINITY, f32::INFINITY];
        let got = Backend::Native.process_shard(&sssp, &csr, &src, &out_deg, &ctx).unwrap();
        for (i, &g) in got.iter().enumerate() {
            let want = sssp.update(i as u32, csr.in_neighbors(i as u32), &src, &out_deg, &ctx);
            assert_eq!(g, want, "v{i}");
        }
        let wcc = Wcc;
        let src: Vec<f32> = (0..4).map(|v| v as f32).collect();
        let got = Backend::Native.process_shard(&wcc, &csr, &src, &out_deg, &ctx).unwrap();
        // v0: min(old=0, src{1,2}) = 0; v1: min(1, src{3}) = 1;
        // v2: min(2, src{0,1}) = 0; v3: no in-edges => old = 3
        assert_eq!(got, vec![0.0, 1.0, 0.0, 3.0]);
    }
}
