//! The vertex-centric sliding window engine (paper §II-C, Algorithm 1).

mod backend;
mod governor;
pub mod partition;
mod shared;
pub mod simd;
pub mod standing;
mod stats;
mod vsw;

pub use backend::{
    process_rows, process_rows_cfg, Backend, CsrRows, DeltaRows, DvRows, EdgeSource, ViewRows,
};
pub use governor::{Governor, GovernorConfig};
pub use shared::SharedSlice;
pub use standing::{Advance, AdvanceMode, WatchOutcome};
pub use stats::{AnyRunResult, IterStats, RunResult, RunStats};
pub use vsw::{EngineConfig, EpochState, VswEngine, WarmStart};
