//! The vertex-centric sliding window engine (paper §II-C, Algorithm 1).

mod backend;
mod governor;
mod shared;
mod stats;
mod vsw;

pub use backend::{process_rows, Backend, CsrRows, DeltaRows, DvRows, EdgeSource, ViewRows};
pub use governor::{Governor, GovernorConfig};
pub use shared::SharedSlice;
pub use stats::{AnyRunResult, IterStats, RunResult, RunStats};
pub use vsw::{EngineConfig, EpochState, VswEngine, WarmStart};
